"""repro.dse: sweep-spec enumeration, analysis-cache memoization, Pareto
extraction, the host-model axis, process-pool store sharing, and an
end-to-end mini-sweep against the unmemoized pipeline."""
import dataclasses
import itertools

import pytest

from repro.core import OffloadConfig, profile_system, trace_program
from repro.core.host_model import HOST_PRESETS
from repro.dse import (CacheOption, DSEEngine, HostOption, SweepSpace,
                       pareto_front)
from repro.dse.space import CACHE_PRESETS, LEVEL_PRESETS
from repro.workloads import build


# ----------------------------------------------------------- enumeration
def test_space_enumeration_deterministic():
    space = SweepSpace(workloads=("KM", "NB"),
                       caches=("32K+256K", "64K+2M"),
                       cim_levels=("L1_only", "both"),
                       techs=("sram", "fefet"))
    pts1, pts2 = space.points(), space.points()
    assert pts1 == pts2
    assert len(pts1) == len(space) == 16
    assert [p.index for p in pts1] == list(range(16))
    # workload-major: all points sharing one analysis key are contiguous
    keys = [p.analysis_key for p in pts1]
    n_runs = len([k for k, _ in itertools.groupby(keys)])
    assert n_runs == len(set(keys)) == 4
    # first block is KM on the first cache
    assert pts1[0].workload == "KM" and pts1[0].cache.name == "32K+256K"
    assert pts1[0].tech == "sram" and pts1[1].tech == "fefet"


def test_space_rejects_unknown_names():
    with pytest.raises(KeyError):
        SweepSpace(workloads=("KM",), caches=("1G+2G",)).points()
    with pytest.raises(KeyError):
        SweepSpace(workloads=("KM",), techs=("memristor",))
    with pytest.raises(KeyError):
        SweepSpace(workloads=("KM",), cim_sets=("everything",))
    with pytest.raises(KeyError):
        SweepSpace(workloads=("KM",), cim_levels=("L9_only",)).points()


def test_cache_option_names_match_presets():
    # display names stay consistent however the option was built (the
    # analysis cache itself keys on the full geometry, not the name)
    for name, levels in CACHE_PRESETS.items():
        assert CacheOption.of(levels).name == name
        assert CacheOption.of(name).levels == levels


def test_analysis_key_distinguishes_same_size_different_assoc():
    from repro.core.cache import CacheConfig, L2_256K
    a = CacheOption.of((CacheConfig("L1", 32 * 1024, 4), L2_256K))
    b = CacheOption.of((CacheConfig("L1", 32 * 1024, 8), L2_256K))
    assert a.name == b.name                       # same sizes, same label...
    pa = SweepSpace(workloads=("KM",), caches=(a,)).points()[0]
    pb = SweepSpace(workloads=("KM",), caches=(b,)).points()[0]
    assert pa.analysis_key != pb.analysis_key     # ...but never one trace


def test_point_offload_config():
    space = SweepSpace(workloads=("KM",), cim_levels=("L2_only",),
                       cim_sets=("logic",))
    (p,) = space.points()
    cfg = p.offload_config()
    assert cfg.cim_levels == ("L2",)
    assert cfg.cim_set == frozenset({"and", "or", "xor"})


# -------------------------------------------------------------- host axis
def test_host_axis_enumeration():
    space = SweepSpace(workloads=("KM",), techs=("sram", "fefet"),
                       hosts=("A9-1GHz", "inorder-1GHz"))
    pts = space.points()
    assert len(pts) == len(space) == 4
    # host iterates innermost (pricing-only: variants stay adjacent)...
    assert [p.host.name for p in pts[:2]] == ["A9-1GHz", "inorder-1GHz"]
    assert pts[0].tech == pts[1].tech == "sram"
    # ...and never perturbs the analysis key
    assert len({p.analysis_key for p in pts}) == 1
    assert pts[1].label.endswith("/inorder-1GHz")
    with pytest.raises(KeyError):
        SweepSpace(workloads=("KM",), hosts=("pentium-133MHz",))
    assert HostOption.of(HOST_PRESETS["A9-2GHz"]).name == "A9-2GHz"


def test_custom_host_model_never_shadows_preset():
    """A hand-built HostModel carrying a preset's default name must get a
    distinct label, so its records can't be conflated with the preset's."""
    from repro.core.host_model import HostModel
    custom = HostModel(pipeline_pj=999.0)        # name defaults to A9-1GHz
    opt = HostOption.of(custom)
    assert opt.name == "custom(A9-1GHz)"
    pts = SweepSpace(workloads=("KM",), hosts=(custom, "A9-1GHz")).points()
    assert pts[0].host.name != pts[1].host.name
    # the engine-default path gets the same guard
    (rec,) = DSEEngine(host=custom).run(SweepSpace(workloads=("NB",))).records
    assert rec.host == "custom(A9-1GHz)"


def test_host_axis_prices_distinct_records():
    """3+ presets over one workload: zero extra analysis work, but every
    host yields its own energy/speedup numbers all the way into the
    Pareto/markdown reports."""
    hosts = ("A9-1GHz", "inorder-1GHz", "big-OoO-2GHz")
    eng = DSEEngine()
    results = eng.run(SweepSpace(workloads=("NB",), hosts=hosts))
    assert len(results) == 3
    assert results.stats["trace_builds"] == 1      # host is pricing-only
    assert results.stats["offload_builds"] == 1
    assert [r.host for r in results] == list(hosts)
    priced = {(r.energy_improvement, r.speedup) for r in results}
    assert len(priced) == 3                        # genuinely distinct
    md = results.to_markdown()
    for h in hosts:
        assert h in md                             # table + Pareto labels
    front = results.pareto(("energy_improvement", "speedup"))
    assert front and all(r.host in hosts for r in front)


def test_default_host_matches_engine_host():
    """hosts=(None,) (the default) prices with the engine's host and
    labels records with its name — four-axis sweeps are unchanged."""
    (rec,) = DSEEngine().run(SweepSpace(workloads=("NB",))).records
    assert rec.host == "A9-1GHz"
    rep = profile_system(trace_program(*_nb()), OffloadConfig())
    assert rec.energy_improvement == pytest.approx(rep.energy_improvement)


def _nb():
    fn, args = build("NB")
    return (fn,) + tuple(args)


# ------------------------------------------------------------ memoization
def test_analysis_runs_once_per_workload():
    """N configs over one workload => exactly one trace/IDG pass (the
    tentpole guarantee) and one candidate selection per offload config."""
    space = SweepSpace(workloads=("KM",),
                       cim_levels=("L1_only", "L2_only", "both"),
                       techs=("sram", "fefet"))
    eng = DSEEngine(executor="thread", max_workers=4)
    results = eng.run(space)
    assert len(results) == 6
    assert eng.analysis.trace_builds == 1
    assert eng.analysis.offload_builds == 3          # one per level set
    # tech axis is pricing-only: re-running adds zero analysis work
    results2 = eng.run(space)
    assert eng.analysis.trace_builds == 1
    assert eng.analysis.offload_builds == 3
    # per-run stats are deltas: the second run built nothing
    assert results2.stats["trace_builds"] == 0
    assert results2.stats["offload_builds"] == 0
    assert [r.energy_improvement for r in results2] == \
        [r.energy_improvement for r in results]


def test_engine_matches_unmemoized_pipeline():
    """Engine records == direct trace->select->price, point by point."""
    space = SweepSpace(workloads=("NB",), caches=("32K+256K",),
                       cim_levels=("L1_only", "both"), techs=("sram", "fefet"))
    records = DSEEngine(executor="serial").run(space).records
    fn, args = build("NB")
    tr = trace_program(fn, *args, cache_levels=CACHE_PRESETS["32K+256K"])
    for rec in records:
        cfg = OffloadConfig(cim_levels=LEVEL_PRESETS[
            {"L1": "L1_only", "L2": "L2_only", "L1+L2": "both"}[rec.cim_levels]])
        rep = profile_system(tr, cfg, tech=rec.tech)
        assert rec.energy_improvement == pytest.approx(rep.energy_improvement)
        assert rec.speedup == pytest.approx(rep.speedup)
        assert rec.macr == pytest.approx(rep.macr)


# ------------------------------------------------------- hashability (bugfix)
def test_host_carrying_sweep_point_is_hashable():
    """Regression: hash(SweepPoint) raised TypeError whenever the point
    carried a HostOption — the HostModel.unit_pj dict defeated the frozen
    dataclass's generated __hash__ — which made set/dict dedup of priced
    points (the adaptive driver's backbone) impossible."""
    pts = SweepSpace(workloads=("KM",),
                     hosts=("A9-1GHz", "inorder-1GHz")).points()
    assert len({hash(p) for p in pts}) == 2          # no TypeError, distinct
    assert hash(HostOption.of("A9-2GHz")) == hash(HostOption.of("A9-2GHz"))
    # equal models hash equal however they were built
    from repro.core.host_model import HostModel
    assert hash(HostModel()) == hash(HOST_PRESETS["A9-1GHz"])
    # identity ignores index; set dedup across rounds relies on .key
    p2 = dataclasses.replace(pts[0], index=99)
    assert p2.key == pts[0].key and len({pts[0].key, p2.key}) == 1


def test_host_model_unit_pj_frozen_but_dict_compatible():
    import pickle
    from repro.core.host_model import HostModel
    m = HostModel(unit_pj={"IntAlu": 1.0})           # plain dict accepted
    assert m.unit_pj == {"IntAlu": 1.0}              # dict equality intact
    assert m.unit_pj.get("IntAlu") == 1.0
    with pytest.raises(TypeError):
        m.unit_pj["IntAlu"] = 2.0
    # pickling across the process pool must survive the frozen mapping
    clone = pickle.loads(pickle.dumps(m))
    assert clone == m and hash(clone) == hash(m)
    # HOST_PRESETS equality lookup in HostOption.of stays intact
    assert HostOption.of(pickle.loads(pickle.dumps(
        HOST_PRESETS["inorder-1GHz"]))).name == "inorder-1GHz"


# ----------------------------------------------------------------- pareto
@dataclasses.dataclass
class _Pt:
    name: str
    energy_improvement: float
    speedup: float


def test_pareto_hand_built():
    pts = [_Pt("a", 2.0, 1.0),     # on the front (best energy)
           _Pt("b", 1.5, 1.5),     # on the front (trade-off)
           _Pt("c", 1.0, 2.0),     # on the front (best speedup)
           _Pt("d", 1.4, 1.4),     # dominated by b
           _Pt("e", 1.0, 2.0)]     # duplicate of c: kept (weak dominance)
    front = pareto_front(pts, ("energy_improvement", "speedup"))
    assert [p.name for p in front] == ["a", "b", "c", "e"]


def test_pareto_min_objective_and_dicts():
    rows = [{"cost": 1.0, "speedup": 1.0},
            {"cost": 2.0, "speedup": 3.0},
            {"cost": 2.0, "speedup": 2.0}]     # dominated (same cost, slower)
    front = pareto_front(rows, (("cost", "min"), "speedup"))
    assert front == rows[:2]
    with pytest.raises(ValueError):
        pareto_front(rows, (("cost", "sideways"),))
    with pytest.raises(ValueError):
        pareto_front(rows, ())


def test_pareto_single_objective_is_argmax():
    pts = [_Pt("a", 1.0, 9.0), _Pt("b", 3.0, 0.1), _Pt("c", 2.0, 5.0)]
    front = pareto_front(pts, ("energy_improvement",))
    assert [p.name for p in front] == ["b"]


def test_pareto_excludes_non_finite_records():
    """Regression: NaN compares false both ways, so a NaN-valued record
    used to sit on *every* frontier (nothing dominated it); an inf record
    flushed everything else off.  Both must be dropped deterministically."""
    nan, inf = float("nan"), float("inf")
    pts = [_Pt("ok", 2.0, 1.0),
           _Pt("also-ok", 1.0, 2.0),
           _Pt("nan-energy", nan, 99.0),
           _Pt("nan-speedup", 5.0, nan),
           _Pt("inf", inf, inf),
           _Pt("neg-inf", -inf, 3.0)]
    front = pareto_front(pts, ("energy_improvement", "speedup"))
    assert [p.name for p in front] == ["ok", "also-ok"]
    # all-degenerate input yields an empty frontier, not a NaN one
    assert pareto_front(pts[2:], ("energy_improvement", "speedup")) == []
    # min-objectives get the same guard
    rows = [{"cost": 1.0, "speedup": 1.0}, {"cost": nan, "speedup": 9.0}]
    assert pareto_front(rows, (("cost", "min"), "speedup")) == rows[:1]


def test_best_excludes_non_finite_metric():
    """Regression: SweepResults.best used max(), and max() over NaN is
    order-dependent garbage — NaN records must never win."""
    from repro.dse import SweepRecord, SweepResults

    def rec(i, energy):
        return SweepRecord(
            index=i, workload="NB", cache="32K+256K", cim_levels="L1+L2",
            tech="sram", cim_set="stt", host="A9-1GHz",
            energy_improvement=energy, speedup=1.0, macr=0.1, macr_l1=0.1,
            base_energy_pj=1.0, cim_energy_pj=1.0, base_cycles=1.0,
            cim_cycles=1.0, base_runtime_ms=1.0, cim_runtime_ms=1.0,
            processor_ratio=0.5, cache_ratio=0.5, n_instructions=1,
            n_mem_accesses=1, n_candidates=1, n_cim_ops=1)

    results = SweepResults(records=[rec(0, float("nan")), rec(1, 2.0),
                                    rec(2, float("inf")), rec(3, 3.0)])
    assert results.best("energy_improvement").index == 3
    all_bad = SweepResults(records=[rec(0, float("nan"))])
    with pytest.raises(ValueError):
        all_bad.best("energy_improvement")


# ------------------------------------------------------------ end-to-end
def test_mini_sweep_2x2x2_end_to_end():
    """2 caches x 2 level sets x 2 techs over one workload: full engine run
    with reporting, Pareto, and the exact analysis-cost accounting."""
    space = SweepSpace(workloads=("NB",),
                       caches=("32K+256K", "64K+256K"),
                       cim_levels=("L1_only", "both"),
                       techs=("sram", "fefet"))
    eng = DSEEngine()
    results = eng.run(space)
    assert len(results) == 8
    assert [r.index for r in results] == list(range(8))
    st = results.stats
    assert st["trace_builds"] == space.n_analyses() == 2
    assert st["offload_builds"] == 4                 # 2 caches x 2 level sets

    for r in results:
        assert r.workload == "NB"
        assert r.base_energy_pj > 0 and r.cim_energy_pj > 0
        assert r.n_instructions > 0
        assert 0.0 <= r.macr <= 1.0

    best = results.best("energy_improvement")
    assert best.energy_improvement == max(r.energy_improvement
                                          for r in results)
    front = results.pareto(("energy_improvement", "speedup"))
    assert front and all(rec in results.records for rec in front)
    assert best in front                              # argmax is never dominated

    md = results.to_markdown()
    assert "Pareto frontier" in md and "| NB |" in md
    doc = results.to_json()
    assert '"records"' in doc and '"energy_improvement"' in doc


# ------------------------------------------------- process-pool store path
def test_process_executor_one_global_build_per_key(tmp_path):
    """Spawned workers route through the shared AnalysisStore: every
    analysis key is built exactly once globally (not once per worker), and
    a second engine over the same store builds nothing at all."""
    space = SweepSpace(workloads=("NB",), caches=("32K+256K", "64K+256K"),
                       cim_levels=("L1_only", "both"))
    eng = DSEEngine(executor="process", max_workers=2, store=tmp_path)
    r1 = eng.run(space)
    assert len(r1) == 4
    assert r1.stats["trace_builds"] == 2           # == distinct analysis keys
    assert r1.stats["offload_builds"] == 4         # 2 caches x 2 level sets

    r2 = DSEEngine(executor="process", max_workers=2, store=tmp_path).run(space)
    assert r2.stats["trace_builds"] == 0           # all workers hit the store
    assert r2.stats["offload_builds"] == 0
    assert r2.stats["store_l1_hits"] >= 2
    assert [r.energy_improvement for r in r2] == \
        [r.energy_improvement for r in r1]

    # matches the shared-cache thread path bit-for-bit
    r3 = DSEEngine(executor="thread").run(space)
    assert [r.energy_improvement for r in r3] == \
        [r.energy_improvement for r in r1]
