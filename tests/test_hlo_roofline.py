"""TPU-mode analysis: collective parsing, fusion candidates, roofline math,
and the sharding machinery lowered on a multi-device mesh (subprocess)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo import collective_bytes, fusion_candidates, shape_bytes
from repro.core.tpu_model import (V5E, model_flops, roofline_terms,
                                  step_energy_pj)


def test_shape_bytes():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert shape_bytes("pred[]") == 0 or shape_bytes("pred[2]") == 2


def test_collective_parse_synthetic():
    hlo = textwrap.dedent("""
      %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
      %ag = bf16[2,512]{1,0} all-gather(bf16[1,512]{1,0} %y), dimensions={0}
      %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
      %cp = f32[16]{0} collective-permute(f32[16]{0} %w)
    """)
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 4096
    assert got["all-gather"] == 2 * 512 * 2
    assert got["reduce-scatter"] == 1024
    assert got["collective-permute"] == 64
    assert got["total"] == 4096 + 2048 + 1024 + 64
    assert got["all-reduce_count"] == 1


def test_fusion_candidates_chain():
    def f(x, y):
        a = x + y
        b = a * 2.0
        c = jnp.tanh(b)
        return c @ y.T                                # matmul ends the chain
    x = jnp.zeros((256, 256), jnp.float32)
    y = jnp.zeros((256, 256), jnp.float32)
    rep = fusion_candidates(jax.make_jaxpr(f)(x, y))
    assert rep.candidates, "elementwise chain must be found"
    big = max(rep.candidates, key=lambda c: c.n_ops)
    assert big.n_ops >= 3
    # two intermediates (a, b) * 2 (store+load) * 256KB
    assert big.saved_bytes == 2 * 2 * 256 * 256 * 4
    assert 0.0 < rep.tpu_macr < 1.0


def test_fusion_respects_multi_consumer():
    def f(x):
        a = x + 1.0
        return a * 2.0 + jnp.tanh(a)                  # `a` has two consumers
    x = jnp.zeros((512, 512), jnp.float32)
    rep = fusion_candidates(jax.make_jaxpr(f)(x))
    for c in rep.candidates:
        assert c.saved_bytes >= 0


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9, 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    t2 = roofline_terms(197e12 * 3, 819e9, 0, 8)
    assert t2.dominant == "compute"
    assert t2.bound_s == pytest.approx(3.0)
    assert 0 < t2.roofline_fraction <= 1.0


def test_model_flops():
    assert model_flops(1_000, 10, "train") == 6e4
    assert model_flops(1_000, 10, "serve") == 2e4
    e = step_energy_pj(1e12, 1e9, 1e6, 4)
    assert e["total_pj"] == pytest.approx(
        e["compute_pj"] + e["hbm_pj"] + e["ici_pj"])


# ------------------------------------------------- multi-device lowering
_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.registry import reduced_config
    from repro.configs.base import TrainConfig, ShapeConfig
    from repro.launch.cells import Cell, state_shardings
    from repro.dist import sharding as shd
    from repro.models import inputs as minputs
    from repro.train import steps as steps_mod
    from repro.core.hlo import collective_bytes

    arch = "%s"
    cfg = reduced_config(arch)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    rules = shd.make_rules(cfg, mesh, shape)
    rng = jax.random.PRNGKey(0)
    state_shape = jax.eval_shape(lambda r: steps_mod.init_train_state(r, cfg), rng)
    st_sh = state_shardings(cfg, mesh, state_shape)
    batch_spec = minputs.train_input_specs(cfg, shape)
    batch_sh = shd.batch_input_shardings(mesh, batch_spec, rules)
    fn = steps_mod.make_train_step(cfg, TrainConfig())
    with mesh, shd.use_rules(mesh, rules):
        lowered = jax.jit(fn, in_shardings=(st_sh, batch_sh)).lower(
            state_shape, batch_spec)
        compiled = lowered.compile()
    coll = collective_bytes(compiled.as_text())
    print(json.dumps({"ok": True, "collective_total": coll["total"]}))
""")


@pytest.mark.slow          # 8-device XLA compile in a subprocess, minutes each
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "moonshot-v1-16b-a3b",
                                  "xlstm-125m"])
def test_sharded_lowering_8dev(arch):
    """Reduced config lowers + compiles on a 2x4 (data, model) mesh and the
    compiled module contains cross-device collectives."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC % arch],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    assert out["collective_total"] > 0
