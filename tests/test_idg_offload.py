"""IDG construction (Algorithm 2) + offload selection (Algorithm 1):
hand-built traces with known ground truth, plus invariants over random
programs (claim disjointness, MACR bounds, leaf rules)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CIM_SET_STT, OffloadConfig, select_candidates,
                        trace_program)
from repro.core.idg import IDGBuilder, build_flow_index
from repro.core.isa import SRC_IMM, SRC_REG, Inst, unit_for


def _mk(seq, op, dst, srcs, addr=None, level="L1", bank=0):
    i = Inst(seq, op, unit_for(op, False), "i", dst, srcs, addr=addr)
    i.level, i.hit, i.bank = level, True, bank
    return i


def _paper_fig6_trace():
    """load r1<-A; load r2<-B; add r0 = r1+r2; store r0->C  (Fig. 3/6)."""
    trace = [
        _mk(0, "load", 1, ((SRC_IMM, 0x100),), addr=0x100),
        _mk(1, "load", 2, ((SRC_IMM, 0x200),), addr=0x200),
        _mk(2, "add", 0, ((SRC_REG, 1), (SRC_REG, 2))),
        _mk(3, "store", None, ((SRC_REG, 0),), addr=0x300),
    ]
    rut = {0: [2], 1: [0], 2: [1]}
    iht = {0: [], 1: [], 2: [(1, 0), (2, 0)], 3: [(0, 0)]}
    return trace, rut, iht


def test_algorithm2_basic_tree():
    trace, rut, iht = _paper_fig6_trace()
    b = IDGBuilder(trace, rut, iht)
    tree = b.create_tree(trace[2], CIM_SET_STT)
    assert tree is not None
    kinds = [k for k, _ in tree.children]
    assert kinds == ["load", "load"]               # Fig. 4(a)
    assert [l.seq for l in tree.load_leaves()] == [0, 1]


def test_algorithm1_selects_the_candidate():
    trace, rut, iht = _paper_fig6_trace()
    res = select_candidates(trace, rut, iht)
    assert len(res.candidates) == 1
    c = res.candidates[0]
    assert c.op_seqs == [2] and c.load_seqs == [0, 1]
    assert c.store_seqs == [3] and c.level == "L1"
    assert c.op_classes == ["CiM-ADD"]
    # all four host instructions leave the pipeline
    assert res.claimed == {0, 1, 2, 3}


def test_composite_pattern_merges():
    """(A+B)^C with the add forwarded in-register (Fig. 4(c))."""
    trace = [
        _mk(0, "load", 1, ((SRC_IMM, 0x100),), addr=0x100),
        _mk(1, "load", 2, ((SRC_IMM, 0x200),), addr=0x200),
        _mk(2, "add", 3, ((SRC_REG, 1), (SRC_REG, 2))),
        _mk(3, "store", None, ((SRC_REG, 3),), addr=0x300),
        _mk(4, "load", 4, ((SRC_IMM, 0x400),), addr=0x400),
        _mk(5, "xor", 5, ((SRC_REG, 3), (SRC_REG, 4))),
        _mk(6, "store", None, ((SRC_REG, 5),), addr=0x500),
    ]
    rut = {1: [0], 2: [1], 3: [2], 4: [4], 5: [5]}
    iht = {0: [], 1: [], 2: [(1, 0), (2, 0)], 3: [(3, 0)], 4: [],
           5: [(3, 0), (4, 0)], 6: [(5, 0)]}
    res = select_candidates(trace, rut, iht)
    assert len(res.candidates) == 1
    c = res.candidates[0]
    assert sorted(c.op_seqs) == [2, 5]             # composite subtree
    assert sorted(c.load_seqs) == [0, 1, 4]
    assert c.op_classes.count("CiM-ADD") == 1


def test_level_lifting_and_moves():
    """Operands split L1/L2 -> offload at L2 with one writeback move."""
    trace = [
        _mk(0, "load", 1, ((SRC_IMM, 0x100),), addr=0x100, level="L1"),
        _mk(1, "load", 2, ((SRC_IMM, 0x200),), addr=0x200, level="L2"),
        _mk(2, "add", 0, ((SRC_REG, 1), (SRC_REG, 2))),
        _mk(3, "store", None, ((SRC_REG, 0),), addr=0x300, level="L1"),
    ]
    rut = {0: [2], 1: [0], 2: [1]}
    iht = {2: [(1, 0), (2, 0)], 3: [(0, 0)], 0: [], 1: []}
    res = select_candidates(trace, rut, iht)
    c = res.candidates[0]
    assert c.level == "L2" and c.moves == 1
    # L1-only CiM cannot host it without cross-level support
    res2 = select_candidates(trace, rut, iht,
                             OffloadConfig(cim_levels=("L1",)))
    assert res2.candidates and res2.candidates[0].level == "L1"
    res3 = select_candidates(trace, rut, iht,
                             OffloadConfig(allow_cross_level=False))
    assert not res3.candidates


def test_same_bank_requirement():
    trace, rut, iht = _paper_fig6_trace()
    trace[1].bank = 3                               # operands in banks 0 / 3
    res = select_candidates(trace, rut, iht,
                            OffloadConfig(require_same_bank=True))
    assert not res.candidates
    trace[1].bank = 0
    res = select_candidates(trace, rut, iht,
                            OffloadConfig(require_same_bank=True))
    assert len(res.candidates) == 1


def test_non_cim_ops_not_offloaded():
    trace, rut, iht = _paper_fig6_trace()
    trace[2].op = "div"                             # not CiM-supported
    res = select_candidates(trace, rut, iht)
    assert not res.candidates


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 32), st.integers(0, 10))
def test_property_invariants_random_programs(n, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(0, 100, (n,)), jnp.int32)
    b = jnp.asarray(r.integers(0, 100, (n,)), jnp.int32)

    def f(a, b):
        return jnp.sum((a + b) ^ (a - b) | b)
    tr = trace_program(f, a, b)
    res = select_candidates(tr.trace, tr.rut, tr.iht)
    # claimed sets disjoint across candidates, MACR within [0, 1]
    seen = set()
    for c in res.candidates:
        ids = set(c.op_seqs) | set(c.load_seqs) | set(c.store_seqs)
        assert not (ids & seen)
        seen |= ids
        # every candidate converts at least one access (its own load leaf
        # or absorbed store; pure-shared-operand candidates convert stores)
        assert c.converted_accesses >= 1
    mb = res.macr_breakdown(tr.trace)
    assert 0.0 <= mb["macr"] <= 1.0
    assert mb["converted"] <= mb["total_accesses"]
