"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret=True)
against its pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _r(seed):
    return np.random.default_rng(seed)


# ------------------------------------------------------------- cim_bitwise
@pytest.mark.parametrize("op", ["and", "or", "xor", "add", "sub"])
@pytest.mark.parametrize("shape", [(8, 128), (100, 300), (17, 1000), (1, 64)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
def test_cim_bitwise_sweep(op, shape, dtype):
    r = _r(hash((op, shape, str(dtype))) % 2**31)
    x = jnp.asarray(r.integers(0, 2**20, shape), dtype)
    y = jnp.asarray(r.integers(0, 2**20, shape), dtype)
    out = ops.cim_bulk(x, y, op=op, interpret=True)
    assert jnp.array_equal(out, ref.cim_bitwise_ref(x, y, op=op))
    assert out.dtype == dtype and out.shape == shape


def test_cim_fused_composite():
    r = _r(0)
    x, y, z = (jnp.asarray(r.integers(0, 2**16, (64, 256)), jnp.int32)
               for _ in range(3))
    out = ops.cim_fused(x, y, z, op1="add", op2="xor", interpret=True)
    assert jnp.array_equal(out, ref.cim_bitwise_fused_ref(x, y, z))


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, S, d)
    (1, 2, 2, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 64),          # MQA
])
@pytest.mark.parametrize("window", [0, 32])
def test_flash_attention_sweep(shape, window):
    B, H, Hkv, S, d = shape
    r = _r(hash((shape, window)) % 2**31)
    q = jnp.asarray(r.normal(size=(B, H, S, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, Hkv, S, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    r = _r(7)
    q = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_matches_model_path():
    """Kernel vs the model stack's chunked-jnp flash (the lowered path)."""
    from repro.models.attention import flash_attention_jnp
    r = _r(9)
    B, H, S, d = 1, 2, 128, 32
    q = jnp.asarray(r.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, S, H, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, S, H, d)), jnp.float32)
    jnp_out = flash_attention_jnp(q, k, v, causal=True, block=64)
    krn_out = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3),
                                  causal=True, block_q=64, block_k=64,
                                  interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(jnp_out), np.asarray(krn_out),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- mlstm_chunk
@pytest.mark.parametrize("shape", [
    # (B, H, S, dh, chunk)
    (1, 1, 64, 16, 16),
    (2, 2, 128, 32, 32),
    (1, 2, 128, 64, 64),
])
def test_mlstm_chunk_sweep(shape):
    B, H, S, dh, chunk = shape
    r = _r(hash(shape) % 2**31)
    q = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    ir = jnp.asarray(r.normal(size=(B, H, S)), jnp.float32)
    fr = jnp.asarray(r.normal(size=(B, H, S)) + 3.0, jnp.float32)
    out = ops.mlstm_chunkwise(q, k, v, ir, fr, chunk=chunk, interpret=True)
    exp = ref.mlstm_chunkwise_ref(q, k, v, ir, fr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunk_invariance():
    """Chunk size must not change the result (algebraic identity)."""
    r = _r(11)
    B, H, S, dh = 1, 1, 64, 16
    q = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, H, S, dh)), jnp.float32)
    ir = jnp.asarray(r.normal(size=(B, H, S)), jnp.float32)
    fr = jnp.asarray(r.normal(size=(B, H, S)) + 3.0, jnp.float32)
    o16 = ops.mlstm_chunkwise(q, k, v, ir, fr, chunk=16, interpret=True)
    o64 = ops.mlstm_chunkwise(q, k, v, ir, fr, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o64),
                               rtol=2e-3, atol=2e-3)
