"""Tests for the ``repro.lint`` static-analysis framework (PR-8 tentpole).

Each checker gets at least one fixture it must *flag* and one it must
*pass*, built as throwaway repo trees under ``tmp_path`` so the checkers
run exactly as they do against the real tree.  Two tree-level contracts
ride along: the committed manifest must match the current source (the
CI lint job's core guarantee), and a full ``run_checkers()`` over the
repo must come back clean.
"""
import json
import pathlib
import textwrap

import pytest

from repro.lint import core as lint_core
from repro.lint import fingerprint as fp
from repro.lint.core import REPO_ROOT, load_baseline, run_checkers
from repro.lint.jit_purity import check_file as jit_check_file
from repro.lint.parity import check_parity
from repro.lint.threads import check_threads


def _write(root: pathlib.Path, relpath: str, source: str) -> pathlib.Path:
    path = root / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return path


# ======================================================================
# version-integrity
# ======================================================================
_OFFLOAD_STUB = '''
    """Selection stub."""
    ANALYSIS_VERSION = 2

    def _place(protos, levels):
        depth_cap = max(levels)
        return [min(p, depth_cap) for p in protos]
'''


def _layer_tree(tmp_path: pathlib.Path) -> pathlib.Path:
    """A minimal repo tree containing every fingerprinted layer module."""
    root = tmp_path / "repo"
    _write(root, "src/repro/core/trace.py",
           "TRACE_VM_VERSION = 2\ndef trace(p):\n    return p + 1\n")
    _write(root, "src/repro/core/columnar.py", "COLS = ('level', 'hit')\n")
    _write(root, "src/repro/core/isa.py", "OP_LOAD = 1\n")
    _write(root, "src/repro/core/offload.py", _OFFLOAD_STUB)
    _write(root, "src/repro/core/idg.py", "def build(t):\n    return t\n")
    _write(root, "src/repro/core/reshape.py", "def reshape(t):\n    return t\n")
    _write(root, "src/repro/dse/backends.py", '''
        TPU_ANALYSIS_VERSION = 1

        class CimBackend:
            def evaluate(self, point):
                return point

        class TpuCandidate:
            pass

        class TpuWorkloadAnalysis:
            pass

        class TpuSelection:
            pass

        class TpuBackend:
            def evaluate(self, point):
                return point

        def arch_fingerprint(workload):
            return workload
    ''')
    _write(root, "src/repro/core/sampling/spec.py",
           "SAMPLING_VERSION = 1\n\nclass SamplingSpec:\n    mode = 'exact'\n")
    _write(root, "src/repro/core/sampling/machines.py",
           "def skim_program(fn):\n    return fn\n")
    _write(root, "src/repro/core/sampling/cluster.py",
           "def build_plan(skim, spec):\n    return skim\n")
    _write(root, "src/repro/core/sampling/pipeline.py",
           "def sampled_structural(w, spec):\n    return w\n")
    _write(root, "src/repro/core/sampling/estimate.py",
           "def estimate(Y, plan, spec):\n    return Y\n")
    _write(root, "src/repro/dse/store.py", '''
        STORE_FORMAT = 2
        NPZ_FORMAT = 1

        def workload_fingerprint(w):
            return w

        class AnalysisStore:
            def _read(self, path, expect_key):
                return None

            def _write(self, path, key, payload):
                pass

            def stats(self):
                return {}
    ''')
    return root


def test_version_integrity_clean(tmp_path):
    root = _layer_tree(tmp_path)
    manifest = tmp_path / "manifest.json"
    fp.save_manifest(root, manifest)
    assert fp.check_versions(root, manifest_path=manifest) == []


def test_version_integrity_flags_change_without_bump(tmp_path):
    root = _layer_tree(tmp_path)
    manifest = tmp_path / "manifest.json"
    fp.save_manifest(root, manifest)
    off = root / "src/repro/core/offload.py"
    off.write_text(off.read_text().replace("max(levels)", "min(levels)"))
    found = fp.check_versions(root, manifest_path=manifest)
    assert len(found) == 1
    assert found[0].symbol == "analysis"
    assert "ANALYSIS_VERSION" in found[0].message
    assert "still 2" in found[0].message


def test_version_integrity_bump_then_update_passes(tmp_path):
    root = _layer_tree(tmp_path)
    manifest = tmp_path / "manifest.json"
    fp.save_manifest(root, manifest)
    off = root / "src/repro/core/offload.py"
    off.write_text(off.read_text()
                   .replace("max(levels)", "min(levels)")
                   .replace("ANALYSIS_VERSION = 2", "ANALYSIS_VERSION = 3"))
    # bumped but not recorded: still an error, pointing at --update-manifest
    found = fp.check_versions(root, manifest_path=manifest)
    assert len(found) == 1 and "--update-manifest" in found[0].message
    fp.save_manifest(root, manifest)
    assert fp.check_versions(root, manifest_path=manifest) == []


def test_version_integrity_ignores_renames_docstrings_comments(tmp_path):
    root = _layer_tree(tmp_path)
    manifest = tmp_path / "manifest.json"
    fp.save_manifest(root, manifest)
    off = root / "src/repro/core/offload.py"
    off.write_text(off.read_text()
                   .replace("depth_cap", "depth_ceiling")
                   .replace('"""Selection stub."""',
                            '"""Rewritten docstring."""\n# new comment'))
    assert fp.check_versions(root, manifest_path=manifest) == []


def test_tpu_layer_symbol_filter_ignores_cim_edits(tmp_path):
    root = _layer_tree(tmp_path)
    manifest = tmp_path / "manifest.json"
    fp.save_manifest(root, manifest)
    be = root / "src/repro/dse/backends.py"
    be.write_text(be.read_text().replace("return point\n\nclass TpuCandidate",
                                         "return point * 2\n\nclass TpuCandidate"))
    found = [f for f in fp.check_versions(root, manifest_path=manifest)
             if f.symbol == "tpu-analysis"]
    assert found == []


def test_committed_manifest_matches_tree():
    """The acceptance gate of the CI lint job: the manifest in the tree
    must describe the tree it ships with."""
    committed = fp.load_manifest()
    assert committed, "manifest.json missing — run --update-manifest"
    current = fp.compute_manifest(REPO_ROOT)
    for name, rec in current.items():
        assert name in committed, f"layer {name} not recorded"
        assert committed[name]["fingerprint"] == rec["fingerprint"], \
            f"{name}: fingerprint drift — bump {rec['version_const']} " \
            f"and run --update-manifest"
        assert committed[name]["version"] == rec["version"], name


# ======================================================================
# jit-purity
# ======================================================================
def test_jit_purity_flags_impure_bodies(tmp_path):
    path = _write(tmp_path, "src/repro/bad.py", '''
        import time, os
        import numpy as np
        import jax


        @jax.jit
        def decorated(x, hist=[]):
            hist.append(x)
            return x + time.time()


        def scanned(carry, x):
            v = np.random.rand()
            return carry + v, x.item()


        def kernel(x):
            if os.environ.get("FLAG"):
                print("tracing")
            return x * 2


        out = jax.lax.scan(scanned, 0, None)
        fn = jax.jit(jax.vmap(kernel))
    ''')
    found = jit_check_file(path, tmp_path)
    messages = "\n".join(f.message for f in found)
    assert "mutable default argument" in messages
    assert "time.time" in messages
    assert "np.random.rand" in messages
    assert ".item() host sync" in messages
    assert "os.environ" in messages or "os.environ.get" in messages
    assert "print()" in messages
    # every finding names the jitted entry it flows through
    assert all("jitted via" in f.message for f in found)


def test_jit_purity_passes_pure_bodies(tmp_path):
    path = _write(tmp_path, "src/repro/good.py", '''
        import os
        import time
        import jax
        import jax.numpy as jnp

        # effects *outside* the jitted body are exactly how it's done
        DEBUG = os.environ.get("DEBUG") == "1"
        t0 = time.time()


        @jax.jit
        def kernel(x, scale=2):
            y = jnp.maximum(x, 0) * scale
            return jnp.sum(y)


        def helper(x):
            print("not jitted, prints are fine")
            return x
    ''')
    assert jit_check_file(path, tmp_path) == []


def test_jit_purity_disable_comment(tmp_path):
    path = _write(tmp_path, "src/repro/waived.py", '''
        import time
        import jax


        @jax.jit
        def kernel(x):
            t = time.time()  # lint: disable=jit-purity
            return x + t
    ''')
    assert jit_check_file(path, tmp_path) == []


# ======================================================================
# accel-parity
# ======================================================================
def _parity_tree(tmp_path, accel_source, oracle_source="", test_source=""):
    root = tmp_path / "repo"
    _write(root, "src/repro/core/accel/kern.py", accel_source)
    if oracle_source:
        _write(root, "src/repro/core/oracle.py", oracle_source)
    _write(root, "tests/test_accel.py", test_source or "# empty\n")
    return root


def test_parity_flags_missing_annotation(tmp_path):
    root = _parity_tree(tmp_path, '''
        def fused_op(a, b):
            return a + b
    ''')
    found = check_parity(root)
    assert any("no `# lint: numpy-twin" in f.message for f in found)


def test_parity_flags_signature_mismatch_and_missing_test(tmp_path):
    root = _parity_tree(tmp_path, '''
        # lint: numpy-twin(repro.core.oracle:fused_ref)
        def fused_op(a, b, out_dtype):
            return a + b
    ''', oracle_source='''
        def fused_ref(a, b):
            return a + b
    ''')
    found = check_parity(root)
    msgs = "\n".join(f.message for f in found)
    assert "does not match numpy twin" in msgs
    assert "no differential test" in msgs


def test_parity_passes_twinned_and_tested(tmp_path):
    root = _parity_tree(tmp_path, '''
        # lint: numpy-twin(repro.core.oracle:Hier.fused_ref)
        def fused_op(a, b):
            return a + b


        # lint: numpy-twin(repro.core.oracle:batched_ref, batched)
        def fused_batch(a, b, n_batch):
            return a + b


        def _private_helper(x):
            return x
    ''', oracle_source='''
        class Hier:
            def fused_ref(self, a, b):
                return a - b


        def batched_ref(a):
            return a
    ''', test_source='''
        def test_fused_op_differential():
            assert fused_op is not None

        def test_fused_batch_differential():
            assert fused_batch is not None
    ''')
    assert check_parity(root) == []


def test_parity_flags_dangling_twin(tmp_path):
    root = _parity_tree(tmp_path, '''
        # lint: numpy-twin(repro.core.oracle:gone)
        def fused_op(a, b):
            return a + b
    ''', oracle_source="X = 1\n",
        test_source="fused_op\n")
    found = check_parity(root)
    assert any("not found" in f.message for f in found)


# ======================================================================
# thread-safety
# ======================================================================
def _threads_tree(tmp_path, engine_source):
    root = tmp_path / "repo"
    _write(root, "src/repro/dse/engine.py", engine_source)
    return root


def test_threads_flags_unguarded_writes(tmp_path):
    root = _threads_tree(tmp_path, '''
        import threading


        class Cache:
            def __init__(self):
                self._lock = threading.Lock()
                self.hits = 0          # lint: guarded-by(_lock)
                self._memo = {}        # lint: guarded-by(_lock)

            def lookup(self, key):
                self.hits += 1                 # unguarded AugAssign
                self._memo[key] = 1            # unguarded subscript store
                self._memo.setdefault(key, 2)  # unguarded mutation call
                with self._lock:
                    fut = lambda: None

                def deferred():
                    self.hits = 0              # closure: lock not proven
                return deferred
    ''')
    found = check_threads(root)
    kinds = "\n".join(f.message for f in found)
    assert len(found) == 4
    assert "augmented write" in kinds
    assert ".setdefault() mutation" in kinds
    assert all("outside `with self._lock:`" in f.message for f in found)


def test_threads_passes_guarded_writes_and_init(tmp_path):
    root = _threads_tree(tmp_path, '''
        import threading


        class Cache:
            def __init__(self):
                self._lock = threading.Lock()
                self.hits = 0      # lint: guarded-by(_lock)
                self._memo = {}    # lint: guarded-by(_lock)
                self.hits = 1      # __init__ is exempt

            def lookup(self, key):
                with self._lock:
                    self.hits += 1
                    self._memo[key] = 1
                    if key:
                        self._memo.pop(key, None)
                local = {}
                local["x"] = 1     # locals are out of scope
                return local
    ''')
    assert check_threads(root) == []


def test_threads_flags_abba_lock_order(tmp_path):
    root = _threads_tree(tmp_path, '''
        import threading


        class TwoLocks:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
                self.x = 0    # lint: guarded-by(_a)

            def path1(self):
                with self._a:
                    with self._b:
                        self.x = 1

            def path2(self):
                with self._b:
                    with self._a:
                        self.x = 2
    ''')
    found = check_threads(root)
    assert any("inconsistent lock order" in f.message
               and "ABBA" in f.message for f in found)


def test_threads_disable_comment(tmp_path):
    root = _threads_tree(tmp_path, '''
        import threading


        class Cache:
            def __init__(self):
                self._lock = threading.Lock()
                self.hits = 0  # lint: guarded-by(_lock)

            def reset_unpublished(self):
                self.hits = 0  # lint: disable=thread-safety
    ''')
    assert check_threads(root) == []


# ======================================================================
# framework: baseline, suppression keys, runner
# ======================================================================
def test_baseline_requires_justification(tmp_path):
    bad = tmp_path / "baseline.json"
    bad.write_text(json.dumps(
        {"suppressions": [{"key": "x:y:z", "justification": "  "}]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(bad)


def test_baseline_suppresses_by_stable_key(tmp_path):
    root = _threads_tree(tmp_path, '''
        import threading


        class Cache:
            def __init__(self):
                self._lock = threading.Lock()
                self.hits = 0  # lint: guarded-by(_lock)

            def racy(self):
                self.hits += 1
    ''')
    found = check_threads(root)
    assert len(found) == 1
    report = run_checkers(root=root, only=("thread-safety",),
                          baseline={found[0].key: "perf counter, test-only"})
    assert report.ok
    assert [w for _, w in report.suppressed] == ["perf counter, test-only"]


def test_runner_rejects_unknown_checker():
    with pytest.raises(ValueError, match="unknown checker"):
        run_checkers(only=("no-such-checker",))


def test_repo_tree_is_lint_clean():
    """`python -m repro.lint` must exit 0 on the tree as committed."""
    report = run_checkers()
    rendered = "\n".join(f.render() for f in report.findings)
    assert report.ok, f"lint findings on the committed tree:\n{rendered}"


def test_comment_annotations_ignore_strings(tmp_path):
    src = _write(tmp_path, "x.py",
                 's = "# lint: guarded-by(_fake)"\n'
                 'y = 1  # lint: guarded-by(_real)\n')
    comments = lint_core.file_comments(src)
    assert list(comments) == [2]
    assert "guarded-by(_real)" in comments[2]
