"""repro.obs: span tracer semantics (nesting, null-span off path,
bounded buffers), trace-context propagation across thread and process
executors, Chrome trace-event export validity, per-stage attribution,
the traced example CLI end to end, the daemon's per-request tracing
surface, and the metrics-registry fixes that rode along (leaf/branch
nest clashes, histogram quantile dedup)."""
import dataclasses
import json
import os
import pathlib
import re
import subprocess
import sys

import pytest

from repro import obs
from repro.dse import AdaptiveDSE, DSEEngine, SweepSpace
from repro.dse.service import (MetricsRegistry, ServiceClient, ServiceError,
                               running_server)
from repro.dse.service.metrics import Histogram

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _no_tracer_leaks():
    """Every test starts and ends with tracing off — a leaked global
    tracer would silently change other tests' hot paths."""
    obs.disable()
    yield
    obs.disable()


def _space():
    return SweepSpace(workloads=("NB",), caches=("32K+256K", "64K+2M"),
                      cim_levels=("L1_only", "both"))


# ---------------------------------------------------------- tracer basics
def test_nested_spans_record_parentage_and_attrs():
    t = obs.enable(obs.Tracer())
    with obs.span("outer", cat="a", k=1):
        with obs.span("inner", cat="b") as inner:
            inner.set(hit=True)
    inner_rec, outer_rec = t.spans()          # finish order: inner first
    assert (inner_rec["name"], outer_rec["name"]) == ("inner", "outer")
    assert inner_rec["parent_id"] == outer_rec["span_id"]
    assert inner_rec["trace_id"] == outer_rec["trace_id"]
    assert outer_rec["parent_id"] is None
    assert outer_rec["attrs"] == {"k": 1}
    assert inner_rec["attrs"] == {"hit": True}
    assert 0 <= inner_rec["dur_ns"] <= outer_rec["dur_ns"]
    assert inner_rec["ts_ns"] >= outer_rec["ts_ns"]


def test_span_records_exception_and_propagates_it():
    t = obs.enable(obs.Tracer())
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    (rec,) = t.spans()
    assert rec["attrs"]["error"] == "ValueError"


def test_separate_roots_get_distinct_trace_ids():
    t = obs.enable(obs.Tracer())
    with obs.span("a"):
        pass
    with obs.span("b"):
        pass
    assert len({s["trace_id"] for s in t.spans()}) == 2


def test_off_hands_out_the_shared_null_span_and_records_nothing():
    assert obs.tracer() is None and not obs.active()
    s = obs.span("x", cat="y", k=1)
    assert s is obs.NULL_SPAN and s is obs.span("z")
    with s as entered:
        assert entered.set(a=1) is entered
    obs.counter("c", 1.0)                      # all no-ops
    assert obs.current() is None
    with obs.attach(None):                     # no-op attach
        assert obs.current() is None


def test_max_spans_bounds_memory_and_counts_drops():
    t = obs.enable(obs.Tracer(max_spans=3))
    for i in range(5):
        with obs.span(f"s{i}"):
            pass
    assert len(t.spans()) == 3
    assert t.dropped == 2


def test_take_removes_one_trace_and_drain_empties():
    t = obs.enable(obs.Tracer())
    with obs.span("a") as sa:
        pass
    with obs.span("b"):
        pass
    taken = t.take(sa.trace_id)
    assert [s["name"] for s in taken] == ["a"]
    assert [s["name"] for s in t.spans()] == ["b"]
    t.counter("c", 2.0)
    spans, samples = t.drain()
    assert [s["name"] for s in spans] == ["b"]
    assert [c["name"] for c in samples] == ["c"]
    assert t.spans() == [] and t.counters() == []


def test_enable_keeps_installed_tracer_unless_given_one():
    t1 = obs.enable()
    assert obs.enable() is t1                  # idempotent
    t2 = obs.enable(obs.Tracer())
    assert obs.tracer() is t2 and t2 is not t1


# -------------------------------------------- engine instrumentation
def test_engine_records_identical_tracing_on_vs_off():
    space = _space()
    base = DSEEngine(executor="serial").run(space)
    assert obs.tracer() is None                # untraced run installs nothing
    t = obs.enable(obs.Tracer())
    traced = DSEEngine(executor="serial").run(space)
    assert [dataclasses.astuple(r) for r in traced] == \
        [dataclasses.astuple(r) for r in base]
    names = {s["name"] for s in t.spans()}
    assert {"dse.run", "cache.trace", "cache.select",
            "backend.evaluate"} <= names


def test_serial_attribution_telescopes_to_wall_clock():
    t = obs.enable(obs.Tracer())
    DSEEngine(executor="serial").run(_space())
    att = t.stage_attribution()
    assert att["n_spans"] > 0
    assert 0.95 <= att["coverage"] <= 1.05, att
    for cat in ("trace", "replay", "select", "price"):
        assert cat in att["stages"], att["stages"].keys()
    # second identical run: every cache layer answers from memo
    DSEEngine(executor="serial").run(_space())


def test_thread_executor_spans_share_one_trace_under_one_root():
    t = obs.enable(obs.Tracer())
    DSEEngine(executor="thread", max_workers=4).run(_space())
    spans = t.spans()
    assert len({s["trace_id"] for s in spans}) == 1
    by_id = {s["span_id"]: s for s in spans}
    roots = [s for s in spans if s["parent_id"] is None]
    assert [r["name"] for r in roots] == ["dse.run"]
    for s in spans:
        if s["parent_id"] is not None:
            assert s["parent_id"] in by_id, s["name"]


def test_process_executor_worker_spans_parent_into_coordinator(tmp_path):
    t = obs.enable(obs.Tracer())
    space = SweepSpace(workloads=("NB",), caches=("32K+256K", "64K+256K"),
                       cim_levels=("L1_only", "both"))
    DSEEngine(executor="process", max_workers=2, store=tmp_path).run(space)
    spans = t.spans()
    assert len({s["pid"] for s in spans}) >= 2       # workers shipped spans
    assert len({s["trace_id"] for s in spans}) == 1  # ...into one trace
    assert [s for s in spans if s["name"] == "worker.chunk"]
    by_id = {s["span_id"]: s for s in spans}
    roots = [s for s in spans if s["parent_id"] is None]
    assert [r["name"] for r in roots] == ["dse.run"]
    for s in spans:
        if s["parent_id"] is not None:
            assert s["parent_id"] in by_id, s["name"]


def test_adaptive_rounds_emit_spans():
    t = obs.enable(obs.Tracer())
    space = SweepSpace(workloads=("NB",),
                       caches=("32K+256K", "64K+256K", "64K+2M"),
                       cim_levels=("L1_only", "L2_only", "both"))
    AdaptiveDSE(space, engine=DSEEngine(executor="serial")).run()
    rounds = [s for s in t.spans() if s["name"] == "adaptive.round"]
    assert rounds
    assert [s["attrs"]["round"] for s in rounds] == list(range(len(rounds)))
    assert all("frontier_size" in s["attrs"] for s in rounds)
    assert rounds[-1]["attrs"]["stable"] is True


# ------------------------------------------------------- chrome export
def test_chrome_export_is_perfetto_valid(tmp_path):
    t = obs.enable(obs.Tracer())
    DSEEngine(executor="serial").run(_space())
    obs.counter("points", 4.0)
    path = tmp_path / "trace.json"
    n = t.export_chrome(path)
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == n == doc["otherData"]["spans"] > 0
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0
    # timestamps rebase to a zero origin
    assert min(e["ts"] for e in events if e["ph"] in "XC") == \
        pytest.approx(0.0)
    # every child's [ts, ts+dur] nests inside its parent's interval
    by_id = {e["args"]["span_id"]: e for e in xs}
    for e in xs:
        ref = e["args"].get("parent_id")
        if ref:
            p = by_id[ref]
            assert e["ts"] >= p["ts"] - 1e-3
            assert e["ts"] + e["dur"] <= p["ts"] + p["dur"] + 1e-3
    cs = [e for e in events if e["ph"] == "C"]
    assert cs and all("value" in e["args"] for e in cs)
    names = {e["name"] for e in events if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names


def test_ndjson_export_round_trips(tmp_path):
    t = obs.enable(obs.Tracer())
    with obs.span("a", cat="x", k=1):
        pass
    path = tmp_path / "spans.ndjson"
    assert t.export_ndjson(path) == 1
    (line,) = path.read_text().splitlines()
    rec = json.loads(line)
    assert rec["name"] == "a" and rec["attrs"] == {"k": 1}


# ----------------------------------------------------------- attribution
def _synth(sid, parent, cat, ts, dur, **attrs):
    return {"name": sid, "cat": cat, "trace_id": "t", "span_id": sid,
            "parent_id": parent, "ts_ns": ts, "dur_ns": dur,
            "pid": 1, "tid": 1, "thread": "main", "attrs": attrs}


def test_stage_attribution_self_time_and_hit_rates():
    spans = [
        _synth("root", None, "engine", 0, 100),
        _synth("a", "root", "trace", 0, 60, source="build", workload="NB"),
        _synth("b", "root", "select", 60, 30, source="memo", workload="NB"),
    ]
    att = obs.stage_attribution(spans)
    assert att["wall_s"] == pytest.approx(100e-9)
    assert att["attributed_s"] == pytest.approx(100e-9)
    assert att["coverage"] == pytest.approx(1.0)
    assert att["stages"]["engine"]["self_s"] == pytest.approx(10e-9)
    assert att["stages"]["trace"]["hit_rate"] == 0.0
    assert att["stages"]["select"]["hit_rate"] == 1.0
    assert att["workloads"]["NB"]["trace"] == pytest.approx(60e-9)
    md = obs.attribution_markdown(att)
    assert "| stage |" in md and "| trace |" in md and "| NB |" in md


def test_stage_attribution_orphans_count_as_roots():
    # a span whose parent never reached this tracer (dropped, or a worker
    # chunk that died) must not vanish from wall-clock accounting
    att = obs.stage_attribution([_synth("x", "missing", "trace", 0, 50)])
    assert att["wall_s"] == pytest.approx(50e-9)
    assert att["coverage"] == pytest.approx(1.0)


def test_build_tree_nests_children_and_orphans():
    spans = [_synth("root", None, "engine", 0, 100),
             _synth("kid2", "root", "select", 60, 30),
             _synth("kid1", "root", "trace", 0, 60),
             _synth("lost", "missing", "price", 5, 1)]
    roots = obs.build_tree(spans)
    assert [r["span_id"] for r in roots] == ["root", "lost"]
    assert [c["span_id"] for c in roots[0]["children"]] == ["kid1", "kid2"]


# ----------------------------------------------- example CLI end to end
def test_example_cli_writes_valid_trace_and_report(tmp_path):
    """Acceptance: a cold --trace run produces a Perfetto-loadable file
    and --trace-report attribution sums to within 5% of wall-clock."""
    trace = tmp_path / "trace.json"
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "examples/dse_cim.py", "--workload", "NB",
         "--trace", str(trace), "--trace-report"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(trace.read_text())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert xs and all(e["args"].get("trace_id") for e in xs)
    assert "| stage |" in proc.stdout
    footer = proc.stdout.strip().splitlines()[-1]
    m = re.search(r"\((\d+(?:\.\d+)?)%\)$", footer)
    assert m, footer
    assert 95.0 <= float(m.group(1)) <= 105.0


# -------------------------------------------------- daemon tracing plane
def test_service_requests_traced_and_queryable():
    with running_server(max_workers=4) as (url, _service):
        client = ServiceClient(url)
        r1 = client.sweep(["NB"], caches=["32K+256K"])
        r2 = client.sweep(["NB"], caches=["32K+256K", "64K+2M"])
        assert r1.trace_id and r2.trace_id
        assert r1.trace_id != r2.trace_id      # one root span per request
        tree = client.trace(r2.trace_id)
        assert tree["trace_id"] == r2.trace_id
        (root,) = tree["spans"]
        assert root["name"] == "http.sweep" and root["children"]
        assert tree["n_spans"] >= 2
        with pytest.raises(ServiceError) as exc:
            client.trace("0" * 16)
        assert exc.value.status == 404
        m = client.metrics()
        assert m["obs"]["tracing"] is True
        assert m["obs"]["buffered_traces"] == 2
        assert m["obs"]["dropped_spans"] == 0
        assert m["service"]["obs"]["spans"] >= tree["n_spans"]
        assert m["service"]["obs"]["stage_self_s"]
    # running_server owned the tracer, so exit restores tracing-off
    assert obs.tracer() is None


# ------------------------------------- metrics registry fixes (satellite)
def test_metrics_nest_leaf_then_branch_keeps_both():
    reg = MetricsRegistry()
    reg.counter("a")                 # leaf "a" registers first (counters
    reg.gauge_inc("a.b", 2)          # nest before gauges in snapshot())
    snap = reg.snapshot()
    assert snap["a"] == 1
    assert snap["a.b"] == 2          # literal dotted key, not dropped


def test_metrics_nest_branch_then_leaf_keeps_both():
    reg = MetricsRegistry()
    reg.counter("a.b")
    reg.gauge_inc("a", 5)
    snap = reg.snapshot()
    assert snap["a"]["b"] == 1
    assert snap["a."] == 5           # dotless name vs branch: "." suffix


def test_metrics_nest_same_kind_clash():
    reg = MetricsRegistry()
    reg.counter("x", 3)
    reg.counter("x.y", 7)
    snap = reg.snapshot()
    assert snap["x"] == 3 and snap["x.y"] == 7


def test_metrics_nest_plain_paths_untouched():
    reg = MetricsRegistry()
    reg.counter("requests.sweep", 2)
    reg.gauge_inc("inflight", 1)
    snap = reg.snapshot()
    assert snap["requests"]["sweep"] == 2 and snap["inflight"] == 1


def test_histogram_quantile_matches_snapshot():
    h = Histogram()
    for v in (5.0, 1.0, 3.0, 2.0, 4.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["p50"] == h.quantile(0.50) == 3.0
    assert snap["p90"] == h.quantile(0.90) == 5.0
    assert snap["p99"] == h.quantile(0.99) == 5.0
    assert snap["count"] == 5 and snap["max"] == 5.0
    empty = Histogram()
    assert empty.quantile(0.5) is None
    assert empty.snapshot()["p50"] is None
