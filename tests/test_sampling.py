"""repro.core.sampling: spec codecs, plan construction, the windowed
trace machinery's byte-identity against the exact VM, estimator
unbiasedness (property tests — hypothesis, or the conftest seeded shim),
the degenerate full-coverage plan reproducing exact metrics bit-for-bit,
sampled sweep records through the engine/backend, and request-codec
validation of the ``sampling`` field."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache import L1_32K, L2_256K
from repro.core.offload import OffloadConfig, analyze_trace
from repro.core.profiler import profile_system
from repro.core.reshape import reshape
from repro.core.sampling import (SamplePlan, SampledStructural, SamplingSpec,
                                 build_plan, build_workload, estimate,
                                 sampled_report, sampled_structural,
                                 skim_program, trace_windows)
from repro.core.sampling.estimate import COMPONENTS
from repro.core.sampling.machines import SkimResult
from repro.core.trace import TraceLimits, attach_cache_results, \
    trace_structural
from repro.dse import CimBackend, DSEEngine, SweepSpace
from repro.dse.results import SweepRecord
from repro.dse.service import RequestError, parse_request

LEVELS = (L1_32K, L2_256K)
LIMITS = TraceLimits(max_instructions=1 << 62)
WL = "hmmer"                     # smallest/fastest registry kernel


def _exact_report(workload):
    fn, args = build_workload(workload)
    st_ = trace_structural(fn, *args, limits=LIMITS)
    tr = attach_cache_results(st_, LEVELS)
    analysis = analyze_trace(tr)
    result = analysis.select(OffloadConfig())
    return profile_system(tr, offload=result,
                          reshaped=reshape(analysis.trace, result))


# ----------------------------------------------------------------- spec
def test_spec_key_parse_dict_roundtrip():
    spec = SamplingSpec(mode="phase", interval=1024, budget=16, seed=3,
                        warmup=4096, target_ci=0.05, n_boot=50)
    assert spec.key() == "phase:i1024:b16:s3:w4096:t0.05:r50"
    assert SamplingSpec.parse(
        "phase:interval=1024,budget=16,seed=3,warmup=4096,"
        "target_ci=0.05,n_boot=50") == spec
    assert SamplingSpec.from_dict(spec.to_dict()) == spec
    # exact is the identity: no knobs in the key, default parse
    assert SamplingSpec().key() == "exact"
    assert SamplingSpec.parse("exact") == SamplingSpec()
    # defaults stay out of the key (cache identity must not churn)
    assert SamplingSpec(mode="stratified").key() == "stratified:i2048:b32:s0"


@pytest.mark.parametrize("bad", [
    dict(mode="simpoint"), dict(interval=32), dict(budget=0),
    dict(warmup=-1), dict(target_ci=1.0), dict(confidence=0.3),
    dict(n_boot=5)])
def test_spec_validation(bad):
    with pytest.raises(ValueError):
        SamplingSpec(**{"mode": "stratified", **bad})


def test_spec_parse_rejects_unknown_knob():
    with pytest.raises(ValueError):
        SamplingSpec.parse("phase:windows=4")
    with pytest.raises(ValueError):
        SamplingSpec.from_dict({"mode": "phase", "windows": 4})


# ----------------------------------------------------------------- plans
def _fake_skim(n_int, interval=64, rng=None):
    rng = rng or np.random.default_rng(0)
    feats = rng.uniform(0.0, 5.0, size=(n_int, 6))
    return SkimResult(features=feats, total_virtual=n_int * interval,
                      interval=interval)


def test_plan_full_coverage_degenerates():
    plan = build_plan(_fake_skim(8), SamplingSpec(mode="stratified",
                                                  budget=32))
    assert plan.full and plan.n_windows == 1
    assert plan.windows() == [(0, 8 * 64)]
    assert plan.weights().tolist() == [1.0]


@pytest.mark.parametrize("mode", ["stratified", "phase"])
def test_plan_weights_expand_to_population(mode):
    """Sum of expansion weights == interval count, picks are unique and
    sorted, every cluster is represented."""
    for seed in range(4):
        spec = SamplingSpec(mode=mode, budget=8, seed=seed)
        plan = build_plan(_fake_skim(40), spec)
        assert not plan.full
        assert plan.n_windows == 8
        assert plan.weights().sum() == pytest.approx(plan.n_intervals)
        idx = [p for p, _ in plan.picks]
        assert idx == sorted(idx) and len(set(idx)) == len(idx)
        sampled_clusters = {c for _, c in plan.picks}
        assert sampled_clusters == set(np.unique(plan.cluster_of))


# ------------------------------------------------------------- estimator
def test_estimator_identity_when_every_interval_sampled():
    """Weights of 1 over a full enumeration: totals are exact sums."""
    rng = np.random.default_rng(1)
    n = 12
    Y = rng.uniform(1.0, 2.0, size=(n, len(COMPONENTS)))
    plan = SamplePlan(interval=64, total_virtual=n * 64, mode="stratified",
                      cluster_of=np.arange(n), picks=tuple((i, i)
                                                           for i in range(n)))
    est = estimate(Y, plan, SamplingSpec(mode="stratified", n_boot=10))
    np.testing.assert_allclose(
        [est.totals[c] for c in COMPONENTS], Y.sum(0), rtol=1e-12)
    assert est.ci["energy_improvement"] == 0.0   # singletons: no variance


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 48), st.integers(4, 10))
def test_estimator_unbiased_over_seeds(n_int, budget):
    """Property: the stratified expansion estimator's totals are unbiased —
    the seed-averaged estimate converges on the exact population total."""
    rng = np.random.default_rng(n_int * 101 + budget)
    Y = rng.uniform(1.0, 2.0, size=(n_int, len(COMPONENTS)))
    truth = Y.sum(0)
    acc = np.zeros(len(COMPONENTS))
    seeds = 48
    for seed in range(seeds):
        spec = SamplingSpec(mode="stratified", budget=budget, seed=seed,
                            n_boot=10)
        plan = build_plan(_fake_skim(n_int, rng=np.random.default_rng(7)),
                          spec)
        picked = Y[[p for p, _ in plan.picks]]
        est = estimate(picked, plan, spec)
        acc += [est.totals[c] for c in COMPONENTS]
    # MC error of the mean, not estimator bias: values in [1,2] keep the
    # per-seed relative spread small, so 48 seeds pin the mean to a few %
    np.testing.assert_allclose(acc / seeds, truth, rtol=0.04)


def test_estimator_rejects_shape_mismatch():
    plan = build_plan(_fake_skim(40), SamplingSpec(mode="stratified",
                                                   budget=8))
    with pytest.raises(ValueError):
        estimate(np.ones((3, len(COMPONENTS))), plan,
                 SamplingSpec(mode="stratified"))


# ----------------------------------------------- windowed-trace machinery
def test_full_window_trace_is_byte_identical():
    """One window covering the whole virtual stream must emit exactly the
    exact VM's rows — the foundation of exact-mode byte-identity."""
    fn, args = build_workload(WL)
    st_ = trace_structural(fn, *args, limits=LIMITS)
    skim = skim_program(fn, *args, interval=2048)
    wt = trace_windows(fn, *args, windows=[(0, skim.total_virtual)],
                       limits=LIMITS, expect_total=skim.total_virtual)
    assert wt.marks == [(0, 0, st_.columns.n)]
    a, b = st_.columns.to_arrays(), wt.structural.columns.to_arrays()
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_degenerate_plan_reproduces_exact_metrics():
    """budget >= n_intervals: the sampled pipeline is the identity."""
    rep = _exact_report(WL)
    est = sampled_report(WL, SamplingSpec(mode="stratified"), LEVELS,
                         OffloadConfig())
    assert est.n_windows == 1
    assert est.metrics["energy_improvement"] == pytest.approx(
        rep.energy_improvement, rel=1e-12)
    assert est.metrics["macr"] == pytest.approx(rep.macr, rel=1e-12)
    assert est.metrics["speedup"] == pytest.approx(rep.speedup, rel=1e-12)
    assert est.ci["energy_improvement"] == 0.0


def test_sampled_structural_interleaves_warmup():
    """Genuine sampling: warmup prefixes are traced but only measured
    windows are priced, and measured_marks() indexes the right rows."""
    spec = SamplingSpec(mode="stratified", interval=256, budget=4,
                        warmup=256, seed=1)
    ss = sampled_structural(WL, spec)
    assert not ss.plan.full and len(ss.plan.picks) == 4
    assert len(ss.measured) == 4 and len(ss.marks) > 4
    measured = ss.measured_marks()
    assert [m[0] for m in measured] == sorted(m[0] for m in measured)
    # genuine estimate lands in the exact report's neighborhood (cold
    # cache state bounds accuracy; the benchmark records the exact error)
    rep = _exact_report(WL)
    est = sampled_report(WL, spec, LEVELS, OffloadConfig())
    assert est.n_windows == 4
    assert est.metrics["energy_improvement"] == pytest.approx(
        rep.energy_improvement, rel=0.35)
    assert est.ci["energy_improvement"] >= 0.0


def test_sampled_structural_no_warmup_marks_all_measured():
    spec = SamplingSpec(mode="stratified", interval=256, budget=4,
                        warmup=0, seed=1)
    ss = sampled_structural(WL, spec)
    assert ss.measured == () and len(ss.marks) == 4
    assert ss.measured_marks() == ss.marks


# -------------------------------------------------------- records/backend
def _record(**over):
    base = dict(index=0, workload=WL, cache="32K+256K", cim_levels="L1+L2",
                tech="sram", cim_set="stt", host="A9-1GHz",
                energy_improvement=1.5, speedup=1.1, macr=0.4, macr_l1=0.3,
                base_energy_pj=10.0, cim_energy_pj=6.7, base_cycles=100.0,
                cim_cycles=90.0, base_runtime_ms=0.1, cim_runtime_ms=0.09,
                processor_ratio=0.5, cache_ratio=0.5, n_instructions=1000,
                n_mem_accesses=200, n_candidates=50, n_cim_ops=10)
    base.update(over)
    return SweepRecord(**base)


def test_sweep_record_to_dict_drops_sampling_when_exact():
    rec = _record()
    doc = rec.to_dict()
    assert "sampling" not in doc and "energy_improvement_ci" not in doc
    sampled = dataclasses.replace(rec, sampling="stratified:i64:b4:s0",
                                  energy_improvement_ci=0.01)
    doc = sampled.to_dict()
    assert doc["sampling"] == "stratified:i64:b4:s0"
    assert doc["energy_improvement_ci"] == 0.01


def test_backend_exact_spec_is_byte_identical_to_default():
    """SamplingSpec(mode='exact') through the engine: records equal the
    pre-sampling backend's field for field, with no sampling columns."""
    space = SweepSpace(workloads=(WL,), techs=("sram", "fefet"))
    base = DSEEngine(executor="serial").run(space).records
    exact = DSEEngine(executor="serial",
                      backend=CimBackend(sampling=SamplingSpec())
                      ).run(space).records
    assert [r.to_dict() for r in base] == [r.to_dict() for r in exact]
    assert all(r.sampling == "exact" for r in exact)


def test_backend_sampled_records_carry_key_and_ci():
    spec = SamplingSpec(mode="stratified", interval=256, budget=4,
                        warmup=256, seed=1)
    eng = DSEEngine(executor="serial", backend=CimBackend(sampling=spec))
    (rec,) = eng.run(SweepSpace(workloads=(WL,))).records
    assert rec.sampling == spec.key()
    doc = rec.to_dict()
    assert {"sampling", "energy_improvement_ci", "speedup_ci",
            "macr_ci"} <= doc.keys()
    assert rec.energy_improvement > 0 and rec.energy_improvement_ci >= 0
    # warm repeat prices from the memoized sampled artifacts
    (rec2,) = eng.run(SweepSpace(workloads=(WL,))).records
    assert rec2.to_dict() == doc


# ------------------------------------------------------------------ codec
def test_codec_accepts_sampling_string_and_dict():
    req = parse_request({"workloads": [WL],
                         "sampling": "stratified:interval=256,budget=4"})
    assert req["sampling"] == SamplingSpec(mode="stratified", interval=256,
                                           budget=4)
    req = parse_request({"workloads": [WL],
                         "sampling": {"mode": "phase", "seed": 2}})
    assert req["sampling"] == SamplingSpec(mode="phase", seed=2)
    # absent -> exact
    assert parse_request({"workloads": [WL]})["sampling"].is_exact


@pytest.mark.parametrize("doc,fragment", [
    ({"workloads": ["qwen1.5-0.5b"], "backend": "tpu",
      "sampling": "stratified"}, "tpu"),
    ({"workloads": [WL], "sampling": "simpoint"}, "sampling"),
    ({"workloads": [WL], "sampling": {"mode": "phase", "windows": 4}},
     "sampling"),
    ({"workloads": ["KM@64"]}, "sampling"),
    ({"workloads": ["KM@zero"], "sampling": "stratified"}, "scale"),
])
def test_codec_rejects_bad_sampling(doc, fragment):
    with pytest.raises(RequestError) as err:
        parse_request(doc)
    assert fragment in str(err.value)


def test_codec_scaled_workload_with_sampling_ok():
    req = parse_request({"workloads": ["KM@64"], "sampling": "stratified"})
    assert req["space"].workloads == ("KM@64",)
    assert req["sampling"].mode == "stratified"
