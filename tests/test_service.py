"""repro.dse.service: single-flight coalescing semantics, request codec
validation, the HTTP daemon end to end (sweep + adaptive over real
sockets), event-driven streaming (a round event must reach the client
while the server is still mid-run — no sleeps, gated on events), warm
repeats doing zero work, and the /metrics observability plane."""
import json
import threading
import time

import pytest

from repro.dse import SweepSpace
from repro.dse.service import (DSEService, MetricsRegistry, RequestError,
                               ServiceClient, ServiceError, SingleFlight,
                               parse_request, running_server)
from repro.dse.service.codec import records_json


# ------------------------------------------------------------ singleflight
def _spin_until(predicate, deadline_s=10.0):
    """Bounded spin on real shared state (not a sleep-based guess)."""
    deadline = time.monotonic() + deadline_s
    while not predicate():
        if time.monotonic() > deadline:
            raise AssertionError("condition never became true")


def test_singleflight_coalesces_concurrent_callers():
    """N concurrent callers of one key: the build runs once, every waiter
    receives the leader's value, counters account for all of them."""
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()
    calls = []

    def build():
        calls.append(1)
        entered.set()
        assert release.wait(timeout=10)
        return "artifact"

    results = []

    def caller():
        results.append(sf.do("k", build))

    leader = threading.Thread(target=caller)
    leader.start()
    assert entered.wait(timeout=10)          # the build is now in flight
    waiters = [threading.Thread(target=caller) for _ in range(4)]
    for t in waiters:
        t.start()
    # all four must be *parked on the flight* before the leader finishes
    _spin_until(lambda: sf._flights["k"].waiters == 4)
    assert sf.inflight() == 1
    release.set()
    for t in [leader] + waiters:
        t.join(timeout=10)

    assert len(calls) == 1                   # one build for five callers
    assert [v for v, _ in results] == ["artifact"] * 5
    assert sorted(c for _, c in results) == [False] + [True] * 4
    assert sf.started == 1 and sf.coalesced == 4
    assert sf.inflight() == 0


def test_singleflight_error_propagates_but_is_not_cached():
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()

    def boom():
        entered.set()
        assert release.wait(timeout=10)
        raise RuntimeError("build failed")

    errors = []

    def caller():
        try:
            sf.do("k", boom)
        except RuntimeError as exc:
            errors.append(str(exc))

    leader = threading.Thread(target=caller)
    leader.start()
    assert entered.wait(timeout=10)
    waiter = threading.Thread(target=caller)
    waiter.start()
    _spin_until(lambda: sf._flights["k"].waiters == 1)
    release.set()
    leader.join(timeout=10)
    waiter.join(timeout=10)
    assert errors == ["build failed"] * 2    # leader AND waiter both see it

    # the failure is not cached: the next call starts a fresh flight
    value, coalesced = sf.do("k", lambda: "recovered")
    assert (value, coalesced) == ("recovered", False)
    assert sf.started == 2


def test_singleflight_sequential_calls_each_run():
    """No caching across completed flights — that's the memo's job."""
    sf = SingleFlight()
    assert sf.do("k", lambda: 1) == (1, False)
    assert sf.do("k", lambda: 2) == (2, False)
    assert sf.started == 2 and sf.coalesced == 0


# ------------------------------------------------------------------- codec
def test_parse_request_defaults_and_space():
    req = parse_request({"workloads": ["NB"]})
    assert req["backend"] == "cim" and req["mode"] == "sweep"
    assert isinstance(req["space"], SweepSpace)
    assert len(req["space"]) == 1
    assert req["objectives"] == ("energy_improvement", "speedup")

    req = parse_request({"workloads": ["NB"], "techs": ["sram", "fefet"],
                         "cim_levels": ["L1_only", "both"]})
    assert len(req["space"]) == 4


@pytest.mark.parametrize("doc, fragment", [
    ({"workloads": ["nope"]}, "unknown workload"),
    ({}, "'workloads' is required"),
    ({"workloads": []}, "non-empty list"),
    ({"workloads": ["NB"], "backend": "quantum"}, "unknown backend"),
    ({"workloads": ["NB"], "mode": "exhaustive"}, "unknown mode"),
    ({"workloads": ["NB"], "backend": "tpu"}, "unknown arch"),
    ({"workloads": ["xlstm-125m"], "backend": "tpu",
      "techs": ["sram"]}, "CiM-only axes"),
    ({"workloads": ["NB"], "tpus": ["v5e"]}, "'tpus' is meaningless"),
    ({"workloads": ["xlstm-125m"], "backend": "tpu",
      "tpus": ["warp9"]}, "unknown TPU chip"),
    ({"workloads": ["NB"], "objectives": ["vibes"]}, "unknown objective"),
    ({"workloads": ["NB"], "max_rounds": -1}, "max_rounds"),
])
def test_parse_request_rejects(doc, fragment):
    with pytest.raises(RequestError, match=fragment):
        parse_request(doc)


def test_records_json_sanitizes_nonfinite():
    import dataclasses
    from repro.dse.results import SweepRecord
    fields = {f.name: (float("nan") if f.type == "float" else 0)
              for f in dataclasses.fields(SweepRecord)}
    fields.update(workload="NB", cache="32K+256K", cim_levels="L1",
                  tech="sram", cim_set="stt", host="default", backend="cim",
                  speedup=float("inf"), energy_improvement=2.0)
    (doc,) = records_json([SweepRecord(**fields)])
    assert doc["speedup"] is None                  # inf -> null
    assert doc["energy_improvement"] == 2.0
    json.dumps(doc, allow_nan=False)               # strict-JSON clean


# ----------------------------------------------------------------- metrics
def test_metrics_registry_snapshot():
    m = MetricsRegistry()
    m.counter("points.requested", by=3)
    m.counter("points.requested")
    m.gauge_inc("inflight")
    m.gauge_inc("inflight")
    m.gauge_dec("inflight")
    for v in (0.1, 0.2, 0.3):
        m.observe("latency_s.sweep", v)
    snap = m.snapshot()
    assert snap["points"]["requested"] == 4
    assert snap["inflight"] == 1
    hist = snap["latency_s"]["sweep"]
    assert hist["count"] == 3
    assert hist["max"] == pytest.approx(0.3)
    assert hist["p50"] == pytest.approx(0.2)
    assert m.counter_value("points.requested") == 4


# ------------------------------------------------------------- HTTP daemon
@pytest.fixture(scope="module")
def daemon():
    with running_server(max_workers=4) as (url, service):
        yield url, ServiceClient(url), service


def test_healthz_and_unknown_paths(daemon):
    url, client, _service = daemon
    health = client.healthz()
    assert health["status"] == "ok"
    assert health["backends"] == ["cim", "tpu"]
    with pytest.raises(ServiceError) as err:
        client._get_json("/nope")
    assert err.value.status == 404


def test_sweep_end_to_end(daemon):
    _url, client, _service = daemon
    events = list(client.stream({"workloads": ["NB"],
                                 "techs": ["sram", "fefet"]}))
    assert [e["event"] for e in events] == ["start", "result"]
    assert events[0]["n_points"] == 2
    reply = client.sweep(["NB"], techs=["sram", "fefet"])
    assert len(reply.records) == 2
    assert {r["tech"] for r in reply.records} == {"sram", "fefet"}
    assert all(r["energy_improvement"] > 0 for r in reply.records)
    assert 1 <= len(reply.frontier) <= 2


def test_bad_requests_are_400_not_streams(daemon):
    _url, client, _service = daemon
    for doc in ({"workloads": ["nope"]},
                {"workloads": ["NB"], "backend": "tpu",
                 "techs": ["sram"]},
                {"workloads": ["NB"], "objectives": ["vibes"]}):
        with pytest.raises(ServiceError) as err:
            list(client.stream(doc))
        assert err.value.status == 400

    # a body that isn't JSON at all is a 400 too, not a hung stream
    import http.client
    conn = http.client.HTTPConnection(client.host, client.port, timeout=30)
    try:
        conn.request("POST", "/v1/sweep", body=b"not json{",
                     headers={"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
    finally:
        conn.close()


def test_warm_repeat_does_zero_work(daemon):
    """ISSUE 6 acceptance: a warm daemon answers a repeated exhaustive
    sweep with zero new trace builds (and zero new evaluations)."""
    _url, client, service = daemon
    req = dict(caches=["32K+256K", "64K+256K"], techs=["sram"])
    client.sweep(["NB"], **req)                        # warm it
    m1 = client.metrics()
    reply = client.sweep(["NB"], **req)                # repeat, warm
    m2 = client.metrics()
    assert len(reply.records) == 2
    assert (m2["service"]["points"]["evaluated"]
            == m1["service"]["points"]["evaluated"])
    assert (m2["cache"]["cim"]["layer1"]["builds"]
            == m1["cache"]["cim"]["layer1"]["builds"])
    assert (m2["service"]["points"]["memo_hits"]
            > m1["service"]["points"]["memo_hits"])


def test_concurrent_overlapping_sweeps_dedup(daemon):
    """Four concurrent identical requests on a cold workload: the daemon
    evaluates each unique SweepPoint.key exactly once."""
    url, client, _service = daemon
    m0 = client.metrics()["service"]["points"]
    barrier = threading.Barrier(4)
    failures = []

    def storm():
        local = ServiceClient(url)
        barrier.wait()
        try:
            reply = local.sweep(["LCS"], techs=["sram", "fefet"])
            assert len(reply.records) == 2
        except Exception as exc:  # noqa: BLE001 — surfaced below
            failures.append(exc)

    threads = [threading.Thread(target=storm) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not failures
    m1 = client.metrics()["service"]["points"]
    assert m1["requested"] - m0["requested"] == 8      # 4 clients x 2 points
    assert m1["evaluated"] - m0["evaluated"] == 2      # == unique keys
    saved = (m1["coalesced"] - m0["coalesced"]) + \
        (m1["memo_hits"] - m0["memo_hits"])
    assert saved == 6                                  # every duplicate


def test_metrics_snapshot_shape(daemon):
    _url, client, _service = daemon
    doc = client.metrics()
    assert doc["uptime_s"] >= 0
    assert doc["dedup_ratio"] is None or doc["dedup_ratio"] >= 1
    for backend in ("cim", "tpu"):
        for layer in ("layer1", "layer2"):
            stats = doc["cache"][backend][layer]
            assert set(stats) == {"builds", "hits", "hit_rate"}
    assert "store" not in doc                # no cache_dir on this daemon
    assert doc["service"]["requests"]["sweep"] >= 1
    assert doc["service"]["latency_s"]["sweep"]["count"] >= 1


def test_adaptive_end_to_end(daemon):
    _url, client, _service = daemon
    events = list(client.adaptive_events(
        ["NB"], caches=["32K+256K", "64K+256K"],
        cim_levels=["L1_only", "both"], max_rounds=4))
    kinds = [e["event"] for e in events]
    assert kinds[0] == "start" and kinds[-1] == "result"
    rounds = [e for e in events if e["event"] == "round"]
    assert rounds and [r["round"] for r in rounds] == list(range(len(rounds)))
    assert all("frontier" in r for r in rounds)
    assert events[-1]["n_records"] >= rounds[0]["n_priced"]


# -------------------------------------------------- event-driven streaming
def test_round_events_stream_while_server_still_running(monkeypatch):
    """The streaming guarantee, verified without sleeps: the client must
    receive round 0 while the server's generator is still *blocked* on an
    event only the client-side test releases.  If the server buffered the
    whole response, the first round could never arrive and the stub would
    time out into an in-band error instead."""
    import repro.dse.service.server as server_mod
    from repro.dse.adaptive import RoundEvent, RoundInfo
    from repro.dse.results import SweepResults

    gate = threading.Event()

    def make_info(n, stable):
        return RoundInfo(round=n, n_candidates=1, n_priced=1,
                         frontier_size=0, stable=stable, stats={},
                         elapsed_s=0.0)

    class StubAdaptive:
        def __init__(self, space, engine=None, objectives=None,
                     max_rounds=None):
            pass

        def run_iter(self):
            yield RoundEvent(info=make_info(0, False), frontier=[],
                             results=SweepResults(records=[]))
            if not gate.wait(timeout=30):
                raise RuntimeError("client never received round 0")
            yield RoundEvent(info=make_info(1, True), frontier=[],
                             results=SweepResults(records=[]))

    monkeypatch.setattr(server_mod, "AdaptiveDSE", StubAdaptive)
    with running_server() as (url, _service):
        events = ServiceClient(url).stream({"workloads": ["NB"],
                                            "mode": "adaptive"})
        assert next(events)["event"] == "start"
        first_round = next(events)           # server is parked on `gate`
        assert (first_round["event"], first_round["round"]) == ("round", 0)
        gate.set()                           # only now may round 1 exist
        rest = list(events)
        assert [(e["event"], e.get("round")) for e in rest] == \
            [("round", 1), ("result", None)]
        assert rest[-1]["n_rounds"] == 2


def test_midstream_failure_is_inband_error(monkeypatch):
    """Failures after the 200 commits travel as a terminal error event."""
    import repro.dse.service.server as server_mod

    class ExplodingAdaptive:
        def __init__(self, *a, **k):
            pass

        def run_iter(self):
            raise RuntimeError("pricing exploded")
            yield  # noqa: unreachable — makes this a generator

    monkeypatch.setattr(server_mod, "AdaptiveDSE", ExplodingAdaptive)
    with running_server() as (url, _service):
        events = ServiceClient(url).stream({"workloads": ["NB"],
                                            "mode": "adaptive"})
        assert next(events)["event"] == "start"
        with pytest.raises(ServiceError, match="pricing exploded"):
            list(events)


# ---------------------------------------------------- persistent store plane
def test_store_metrics_and_corrupt_drops_surface(tmp_path):
    """/metrics carries the store counters; a daemon restarted over a
    corrupted cache dir reports the drop (satellite: corrupt-drop counter
    surfaced end-to-end)."""
    with running_server(cache_dir=str(tmp_path)) as (url, _service):
        client = ServiceClient(url)
        client.sweep(["NB"])
        doc = client.metrics()
        assert doc["store"]["corrupt_drops"] == 0
        assert doc["store"]["store_writes"] >= 2

    (blob,) = (p for p in (tmp_path / "layer1").glob("*.npz")
               if ".flow" not in p.name)          # the trace artifact
    blob.write_bytes(b"bit rot")

    with running_server(cache_dir=str(tmp_path)) as (url, _service):
        client = ServiceClient(url)
        reply = client.sweep(["NB"])          # rebuilds through the rot
        assert len(reply.records) == 1
        doc = client.metrics()
        assert doc["store"]["corrupt_drops"] == 1
        assert doc["store"]["store_corrupt_drops"] == 1


def test_jax_backend_zero_recompiles(daemon):
    """ISSUE 7 satellite: under EVA_CIM_ACCEL=jax the daemon batches every
    geometry of a sweep into one replay launch, /metrics exposes the accel
    counters, and repeated sweeps — even through a COLD cache re-replaying
    the same shapes — add zero compiled specializations."""
    from repro.core import accel
    from repro.dse.engine import AnalysisCache
    from repro.dse.space import CacheOption

    _url, client, _service = daemon
    req = dict(caches=["32K+256K", "64K+256K", "64K+2M"], techs=["sram"])
    with accel.use_backend("jax"):
        client.sweep(["KM"], **req)                    # cold: compiles
        m1 = client.metrics()
        assert m1["accel"]["backend"] == "jax"
        compiles = m1["accel"]["jit_compiles"]
        assert compiles > 0
        assert m1["cache"]["cim"]["replay_batches"] >= 1

        client.sweep(["KM"], **req)                    # warm repeat
        m2 = client.metrics()
        assert m2["accel"]["jit_compiles"] == compiles
        assert (m2["cache"]["cim"]["replay_batches"]
                == m1["cache"]["cim"]["replay_batches"])

        # stronger than a memo hit: a fresh cache re-REPLAYS the sweep's
        # geometry batch and still reuses every compiled kernel
        fresh = AnalysisCache()
        fresh.replay_group("KM",
                           [CacheOption.of(n) for n in req["caches"]])
        assert fresh.replay_batches == 1
        assert accel.jit_compiles() == compiles
    m3 = client.metrics()
    assert m3["accel"]["backend"] == "numpy"           # override restored
