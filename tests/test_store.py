"""repro.dse.store: persisted-vs-fresh artifact equality, versioned
invalidation, corrupted-file recovery, cross-engine zero-rebuild runs,
concurrent same-key races (one blob, consistent counters), directory
format-marker compatibility, and backend-namespaced coexistence (CiM +
TPU artifacts in one cache dir)."""
import json
import pickle
import threading

import pytest

from repro.core import profile_system
from repro.core.offload import OffloadConfig
from repro.dse import (AnalysisCache, AnalysisStore, DSEEngine,
                       StoreFormatError, SweepSpace, TpuBackend, TpuOption)
from repro.dse.space import CacheOption
from repro.dse.store import STORE_FORMAT, workload_fingerprint

CACHE = CacheOption.of("32K+256K")
CFG = OffloadConfig()

# the cheapest TPU-mode sweep: one arch, two fusion thresholds
TPU_SPACE = SweepSpace(workloads=("xlstm-125m",),
                       tpus=(TpuOption.of("v5e"),
                             TpuOption(TpuOption.of("v5e").chip, 1 << 18)))


# ----------------------------------------------------------------- keys
def test_keys_are_content_addressed(tmp_path):
    store = AnalysisStore(tmp_path)
    k1 = store.layer1_key("NB", CACHE.levels)
    assert k1 == store.layer1_key("NB", CACHE.levels)        # deterministic
    assert k1 != store.layer1_key("KM", CACHE.levels)        # workload
    other = CacheOption.of("64K+256K")
    assert k1 != store.layer1_key("NB", other.levels)        # geometry
    k2 = store.layer2_key("NB", CACHE.levels, CFG)
    assert k2 != k1
    assert k2 != store.layer2_key("NB", CACHE.levels,
                                  OffloadConfig(cim_levels=("L1",)))
    # fingerprints hash the builder module's source, not just the name
    assert workload_fingerprint("NB") != workload_fingerprint("LCS")


# ------------------------------------------------------------ round-trip
def test_roundtrip_persisted_equals_fresh(tmp_path):
    """A second process (fresh cache, same store) must price identically —
    and without building anything."""
    c1 = AnalysisCache(store=AnalysisStore(tmp_path))
    tr1 = c1.trace("NB", CACHE)
    res1, rs1 = c1.offload("NB", CACHE, CFG)
    assert c1.trace_builds == 1 and c1.offload_builds == 1

    c2 = AnalysisCache(store=AnalysisStore(tmp_path))      # "new process"
    tr2 = c2.trace("NB", CACHE)
    res2, rs2 = c2.offload("NB", CACHE, CFG)
    assert c2.trace_builds == 0 and c2.offload_builds == 0
    assert c2.store.l1_hits >= 1 and c2.store.l2_hits == 1

    # instruction stream survives byte-for-byte (repr covers every field)
    assert len(tr2.trace) == len(tr1.trace)
    assert repr(tr2.trace[0]) == repr(tr1.trace[0])
    assert repr(tr2.trace[-1]) == repr(tr1.trace[-1])
    assert [c.level for c in res2.candidates] == \
        [c.level for c in res1.candidates]
    assert rs2.host_seqs == rs1.host_seqs

    rep1 = profile_system(tr1, offload=res1, reshaped=rs1)
    rep2 = profile_system(tr2, offload=res2, reshaped=rs2)
    assert rep2.energy_improvement == rep1.energy_improvement
    assert rep2.speedup == rep1.speedup
    assert rep2.macr == rep1.macr


def test_layer1_upgraded_with_flow_tables(tmp_path):
    """trace() persists the raw trace; trace_analysis() upgrades the same
    artifact with the flow index so later processes skip analyze_trace."""
    store = AnalysisStore(tmp_path)
    c1 = AnalysisCache(store=store)
    c1.trace("NB", CACHE)
    _, flow = store.load_layer1("NB", CACHE.levels)
    assert flow is None
    c1.trace_analysis("NB", CACHE)
    _, flow = store.load_layer1("NB", CACHE.levels)
    assert flow is not None

    c2 = AnalysisCache(store=AnalysisStore(tmp_path))
    an = c2.trace_analysis("NB", CACHE)
    assert c2.trace_builds == 0
    assert an.flow.reg_consumers                    # rehydrated, non-empty


# ------------------------------------------------------------ invalidation
def test_analysis_version_in_selection_keys(tmp_path, monkeypatch):
    """Selection/flow artifacts are additionally keyed by ANALYSIS_VERSION:
    an algorithm change invalidates them while the trace stays reusable."""
    store = AnalysisStore(tmp_path)
    c = AnalysisCache(store=store)
    c.offload("NB", CACHE, CFG)

    import repro.dse.store as store_mod
    monkeypatch.setattr(store_mod, "ANALYSIS_VERSION",
                        store_mod.ANALYSIS_VERSION + 1)
    bumped = AnalysisStore(tmp_path)
    assert bumped.load_layer2("NB", CACHE.levels, CFG) is None
    tr, flow = bumped.load_layer1("NB", CACHE.levels)
    assert tr is not None and flow is None      # trace reusable, flow not


def test_version_bump_invalidates(tmp_path):
    c1 = AnalysisCache(store=AnalysisStore(tmp_path, version=1))
    c1.trace("NB", CACHE)

    bumped = AnalysisStore(tmp_path, version=2)
    assert bumped.load_layer1("NB", CACHE.levels) is None   # unreachable
    c2 = AnalysisCache(store=bumped)
    c2.trace("NB", CACHE)
    assert c2.trace_builds == 1                             # forced rebuild

    # the old version's artifact is untouched (keys don't collide)
    assert AnalysisStore(tmp_path, version=1).load_layer1(
        "NB", CACHE.levels) is not None


# ---------------------------------------------------------- format marker
def test_fresh_store_writes_format_marker(tmp_path):
    AnalysisStore(tmp_path)
    marker = tmp_path / "FORMAT.json"
    assert json.loads(marker.read_text()) == {"store_format": STORE_FORMAT}
    AnalysisStore(tmp_path)                       # reopening is fine


def test_newer_format_directory_refuses_to_open(tmp_path):
    (tmp_path / "FORMAT.json").write_text(
        json.dumps({"store_format": STORE_FORMAT + 1}))
    with pytest.raises(StoreFormatError, match="newer|STORE_FORMAT"):
        AnalysisStore(tmp_path)
    # ...and through the engine, the error carries the directory name
    with pytest.raises(StoreFormatError, match=str(tmp_path)):
        DSEEngine(store=tmp_path)


def test_older_or_corrupt_marker_is_upgraded(tmp_path):
    (tmp_path / "FORMAT.json").write_text(
        json.dumps({"store_format": STORE_FORMAT - 1}))
    AnalysisStore(tmp_path)                       # per-file stamps protect loads
    assert json.loads((tmp_path / "FORMAT.json").read_text()) == \
        {"store_format": STORE_FORMAT}
    (tmp_path / "FORMAT.json").write_text("not json{")
    AnalysisStore(tmp_path)
    assert json.loads((tmp_path / "FORMAT.json").read_text()) == \
        {"store_format": STORE_FORMAT}


def test_cli_clear_error_on_newer_store(tmp_path, capsys):
    """examples/dse_cim.py must exit 2 with a one-line error (no
    traceback) when --cache-dir points at a newer-format store."""
    import importlib.util
    import pathlib
    cli_path = (pathlib.Path(__file__).resolve().parents[1]
                / "examples" / "dse_cim.py")
    spec = importlib.util.spec_from_file_location("dse_cim_cli", cli_path)
    cli = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cli)
    (tmp_path / "FORMAT.json").write_text(json.dumps({"store_format": 99}))
    rc = cli.main(["--workload", "NB", "--cache-dir", str(tmp_path)])
    err = capsys.readouterr().err
    assert rc == 2
    assert "STORE_FORMAT=99" in err
    assert "Traceback" not in err
    rc = cli.main(["--backend", "tpu", "--workload", "xlstm-125m",
                   "--chips", "v5e", "--thresholds", "16K",
                   "--cache-dir", str(tmp_path)])
    assert rc == 2
    assert "STORE_FORMAT=99" in capsys.readouterr().err


# --------------------------------------------------------------- recovery
def test_corrupt_file_recovery(tmp_path):
    store = AnalysisStore(tmp_path)
    AnalysisCache(store=store).trace("NB", CACHE)
    files = list((tmp_path / "layer1").glob("*.npz"))
    assert len(files) == 1
    files[0].write_bytes(b"not an npz archive")

    fresh = AnalysisStore(tmp_path)
    assert fresh.load_layer1("NB", CACHE.levels) is None
    assert fresh.corrupt_drops == 1
    assert not files[0].exists()                    # dropped, not retried

    c = AnalysisCache(store=fresh)                  # rebuild + re-publish
    c.trace("NB", CACHE)
    assert c.trace_builds == 1
    assert AnalysisStore(tmp_path).load_layer1("NB", CACHE.levels) is not None


def test_bad_payload_is_dropped_and_repaired(tmp_path):
    """An archive whose envelope verifies but whose payload fails
    rehydration must be unlinked — save_layer1 skips existing files, so a
    merely-ignored artifact would never be repaired."""
    import numpy as np
    from repro.dse.store import NPZ_FORMAT
    store = AnalysisStore(tmp_path)
    AnalysisCache(store=store).trace("NB", CACHE)
    (path,) = (tmp_path / "layer1").glob("*.npz")
    key = store.layer1_key("NB", CACHE.levels)
    np.savez_compressed(                      # valid envelope, no columns
        path, meta_store_key=np.frombuffer(key.encode(), dtype=np.uint8),
        meta_npz_format=np.asarray([NPZ_FORMAT], np.int64))

    fresh = AnalysisStore(tmp_path)
    assert fresh.load_layer1("NB", CACHE.levels) is None
    assert fresh.corrupt_drops == 1
    assert not path.exists()                  # dropped, so a rebuild heals it
    c = AnalysisCache(store=fresh)
    c.trace("NB", CACHE)
    assert c.trace_builds == 1
    assert AnalysisStore(tmp_path).load_layer1("NB", CACHE.levels) is not None


def test_foreign_payload_rejected(tmp_path):
    """A well-formed archive that isn't ours (wrong embedded key) is a miss."""
    import numpy as np
    from repro.dse.store import NPZ_FORMAT
    store = AnalysisStore(tmp_path)
    key = store.layer1_key("NB", CACHE.levels)
    path = tmp_path / "layer1" / f"cim-{key}.npz"
    np.savez_compressed(
        path, meta_store_key=np.frombuffer(b"somebody-else", dtype=np.uint8),
        meta_npz_format=np.asarray([NPZ_FORMAT], np.int64))
    assert store.load_layer1("NB", CACHE.levels) is None
    assert store.corrupt_drops == 1

    # ...and a well-formed *pickle* under the npz name is dropped, too
    path.write_bytes(pickle.dumps({"format": STORE_FORMAT,
                                   "key": "somebody-else", "payload": {}}))
    assert store.load_layer1("NB", CACHE.levels) is None
    assert store.corrupt_drops == 2


# ----------------------------------------------------------- two engines
def test_two_engines_share_store_zero_rebuilds(tmp_path):
    space = SweepSpace(workloads=("NB",), cim_levels=("L1_only", "both"),
                       techs=("sram", "fefet"))
    r1 = DSEEngine(store=tmp_path).run(space)
    assert r1.stats["trace_builds"] == 1
    assert r1.stats["offload_builds"] == 2
    assert r1.stats["store_writes"] >= 3            # 1x layer1(+flow) + 2x layer2

    r2 = DSEEngine(store=tmp_path).run(space)       # fresh engine, warm disk
    assert r2.stats["trace_builds"] == 0
    assert r2.stats["offload_builds"] == 0
    assert r2.stats["store_l1_hits"] >= 1
    assert r2.stats["store_l2_hits"] == 2
    assert [r.energy_improvement for r in r2] == \
        [r.energy_improvement for r in r1]
    assert [r.speedup for r in r2] == [r.speedup for r in r1]


def test_engine_rejects_cache_plus_store(tmp_path):
    with pytest.raises(ValueError):
        DSEEngine(cache=AnalysisCache(), store=tmp_path)


def test_store_disk_usage_gauges(tmp_path):
    """stats() reports on-disk bytes per layer and per owning backend —
    absolute gauges, surfaced through SweepResults.stats as well."""
    res = DSEEngine(store=tmp_path).run(SweepSpace(workloads=("NB",)))
    store = AnalysisStore(tmp_path)
    usage = store.disk_usage()
    assert usage["store_bytes_layer1"] > 0
    assert usage["store_bytes_layer2"] > 0
    assert usage["store_bytes_cim"] == usage["store_bytes_total"] == \
        usage["store_bytes_layer1"] + usage["store_bytes_layer2"]
    # engine stats carry the gauges as absolutes (not deltas)
    assert res.stats["store_bytes_total"] == usage["store_bytes_total"]
    # gauges live in stats() alongside the counters
    assert store.stats()["store_bytes_layer1"] == usage["store_bytes_layer1"]


# ------------------------------------------------------------ concurrency
def test_concurrent_caches_race_same_key_one_blob(tmp_path):
    """Two threads — separate caches, separate store handles, one cache
    dir — race the same layer-1/layer-2 key.  Exactly one valid blob per
    layer must exist afterwards, both threads must price identically, and
    the counters must stay consistent (no phantom hits, no corrupt
    drops)."""
    barrier = threading.Barrier(2)
    outcomes, errors = [], []

    def worker():
        cache = AnalysisCache(store=AnalysisStore(tmp_path))
        barrier.wait()                      # collide as hard as possible
        try:
            an = cache.trace_analysis("NB", CACHE)
            res, rs = cache.offload("NB", CACHE, CFG)
            rep = profile_system(cache.trace("NB", CACHE),
                                 offload=res, reshaped=rs)
            outcomes.append((len(an.flow.reg_consumers),
                             rep.energy_improvement, rep.speedup,
                             cache.trace_builds, cache.offload_builds,
                             cache.store.corrupt_drops))
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors
    assert len(outcomes) == 2

    # both threads computed/loaded the *same* analysis and price
    assert outcomes[0][:3] == outcomes[1][:3]
    # each thread built at most once per layer, and nobody saw corruption
    for _, _, _, trace_builds, offload_builds, corrupt in outcomes:
        assert trace_builds <= 1 and offload_builds <= 1
        assert corrupt == 0

    # exactly one blob per artifact on disk (trace npz + flow npz under
    # layer1, one pickle under layer2), and they are valid: a fresh cache
    # rebuilds nothing
    layer1 = sorted(p.name for p in (tmp_path / "layer1").glob("*.npz"))
    assert len(layer1) == 2                       # <key>.npz + <key>.flow npz
    assert len({name.split(".")[0] for name in layer1}) == 1   # same key
    assert len(list((tmp_path / "layer2").glob("*"))) == 1
    fresh = AnalysisCache(store=AnalysisStore(tmp_path))
    fresh.trace_analysis("NB", CACHE)
    fresh.offload("NB", CACHE, CFG)
    assert fresh.trace_builds == 0 and fresh.offload_builds == 0
    assert fresh.store.corrupt_drops == 0


def test_corrupt_drops_surface_in_engine_stats(tmp_path):
    """The corrupt-drop counter rides SweepResults.stats, so CLI surfaces
    (examples/dse_cim.py --cache-dir) and /metrics can report it."""
    res = DSEEngine(store=tmp_path).run(SweepSpace(workloads=("NB",)))
    assert res.stats["store_corrupt_drops"] == 0

    (blob,) = (p for p in (tmp_path / "layer1").glob("*.npz")
               if ".flow" not in p.name)          # the trace artifact
    blob.write_bytes(b"bit rot")
    res2 = DSEEngine(store=tmp_path).run(SweepSpace(workloads=("NB",)))
    assert res2.stats["store_corrupt_drops"] == 1
    assert res2.stats["trace_builds"] == 1          # rebuilt through the rot
    assert [r.energy_improvement for r in res2] == \
        [r.energy_improvement for r in res]


# ------------------------------------------------- backend coexistence
CIM_SPACE = SweepSpace(workloads=("NB",))


def test_two_backends_share_cache_dir_roundtrip(tmp_path):
    """CiM and TPU artifacts coexist in one store directory: each backend's
    second (fresh-engine) run does zero analysis work and prices
    identically, and neither evicts or collides with the other."""
    cim1 = DSEEngine(store=tmp_path).run(CIM_SPACE)
    tpu1 = DSEEngine(store=tmp_path, backend=TpuBackend()).run(TPU_SPACE)
    assert cim1.stats["trace_builds"] == 1
    assert tpu1.stats["trace_builds"] == 1

    cim2 = DSEEngine(store=tmp_path).run(CIM_SPACE)
    tpu2 = DSEEngine(store=tmp_path, backend=TpuBackend()).run(TPU_SPACE)
    assert cim2.stats["trace_builds"] == 0
    assert tpu2.stats["trace_builds"] == 0
    assert tpu2.stats["store_l1_hits"] == 1
    assert [r.energy_improvement for r in cim2] == \
        [r.energy_improvement for r in cim1]
    assert [r.energy_improvement for r in tpu2] == \
        [r.energy_improvement for r in tpu1]
    assert {r.backend for r in tpu2} == {"tpu"}


def test_tpu_version_bump_misses_while_cim_stays_warm(tmp_path, monkeypatch):
    """Bumping a backend's version stamp must invalidate *that* backend's
    persisted artifacts and no one else's."""
    DSEEngine(store=tmp_path).run(CIM_SPACE)
    DSEEngine(store=tmp_path, backend=TpuBackend()).run(TPU_SPACE)

    import repro.dse.backends as backends_mod
    monkeypatch.setattr(backends_mod, "TPU_ANALYSIS_VERSION",
                        backends_mod.TPU_ANALYSIS_VERSION + 1)
    tpu = DSEEngine(store=tmp_path, backend=TpuBackend()).run(TPU_SPACE)
    assert tpu.stats["trace_builds"] == 1          # forced re-analysis
    cim = DSEEngine(store=tmp_path).run(CIM_SPACE)
    assert cim.stats["trace_builds"] == 0          # untouched, still warm


def test_trace_vm_bump_misses_while_tpu_stays_warm(tmp_path):
    """...and symmetrically: a trace-VM version bump (the CiM stamp, held
    by the store) rebuilds CiM analyses while TPU artifacts — keyed by the
    TPU backend's own stamp, not the store's — stay warm."""
    from repro.core.trace import TRACE_VM_VERSION
    DSEEngine(store=tmp_path).run(CIM_SPACE)
    DSEEngine(store=tmp_path, backend=TpuBackend()).run(TPU_SPACE)

    bumped = AnalysisStore(tmp_path, version=TRACE_VM_VERSION + 1)
    cim = DSEEngine(store=bumped).run(CIM_SPACE)
    assert cim.stats["trace_builds"] == 1          # unreachable under v+1
    bumped2 = AnalysisStore(tmp_path, version=TRACE_VM_VERSION + 1)
    tpu = DSEEngine(store=bumped2, backend=TpuBackend()).run(TPU_SPACE)
    assert tpu.stats["trace_builds"] == 0
    assert tpu.stats["store_l1_hits"] == 1
