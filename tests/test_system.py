"""End-to-end behaviour: the full Eva-CiM pipeline reproduces the paper's
qualitative findings, and the DSE axes move in the documented directions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CIM_SET_STT, L1_32K, L1_64K, L2_256K, L2_2M,
                        OffloadConfig, profile_system, trace_program)
from repro.workloads import build


@pytest.fixture(scope="module")
def lcs_trace():
    fn, args = build("LCS")
    return trace_program(fn, *args)


@pytest.fixture(scope="module")
def m2d_trace():
    fn, args = build("M2D")
    return trace_program(fn, *args)


def test_finding_i_cim_vs_regular_accesses(lcs_trace):
    """Finding (i): CiM-supported accesses are comparable to (not vastly
    more than) regular accesses in a real hierarchy — MACR around ~0.5."""
    rep = profile_system(lcs_trace)
    assert 0.3 < rep.macr < 0.95


def test_finding_ii_data_intensive_not_cim_sensitive(lcs_trace, m2d_trace):
    """Finding (ii): M2D is data-intensive but NOT CiM-favorable (float
    IDCT muls don't offload); LCS is."""
    lcs = profile_system(lcs_trace)
    m2d = profile_system(m2d_trace)
    assert lcs.cim_favorable
    assert not m2d.cim_favorable
    assert m2d.macr < lcs.macr
    assert m2d.energy_improvement < lcs.energy_improvement


def test_finding_iii_larger_cache_higher_cim_energy():
    """Finding (iii): growing the arrays raises per-op CiM energy, so the
    energy improvement does not grow with cache size."""
    fn, args = build("KM")
    tr_small = trace_program(fn, *args, cache_levels=(L1_32K, L2_256K))
    tr_big = trace_program(fn, *args, cache_levels=(L1_64K, L2_2M))
    small = profile_system(tr_small)
    big = profile_system(tr_big)
    # per-op CiM energy strictly higher in the big config...
    from repro.core import SRAM
    assert SRAM.energy("CiM-ADD", L2_2M) > SRAM.energy("CiM-ADD", L2_256K)
    # ...and the system-level benefit does not improve
    assert big.energy_improvement <= small.energy_improvement + 0.05


def test_speedup_band(lcs_trace):
    """Paper Table VI: SRAM speedups land in ~1.0-1.5x."""
    rep = profile_system(lcs_trace)
    assert 0.9 <= rep.speedup <= 1.6


def test_fefet_beats_sram_cross_baseline(lcs_trace):
    """Fig. 16: FeFET CiM vs the SRAM non-CiM baseline >= SRAM CiM."""
    sram = profile_system(lcs_trace, tech="sram")
    fefet = profile_system(lcs_trace, tech="fefet")
    sram_imp = sram.base.total / sram.cim.total
    fefet_imp = sram.base.total / fefet.cim.total
    assert fefet_imp >= sram_imp * 0.95


def test_quickstart_example_runs(capsys):
    import examples.quickstart as q
    assert q.main() == 0
    out = capsys.readouterr().out
    assert "energy improvement" in out
