"""Trace-VM correctness: the interpreter must compute exactly what XLA
computes, while emitting a well-formed I-state stream (RUT/IHT coherent,
register file bounded, pattern variants present)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import trace_program
from repro.core.isa import SRC_IMM, SRC_REG


def _check_outputs(fn, *args):
    tr = trace_program(fn, *args)
    expected = jax.jit(fn)(*args)
    exp_leaves = jax.tree_util.tree_leaves(expected)
    assert len(tr.outputs) == len(exp_leaves)
    for got, exp in zip(tr.outputs, exp_leaves):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)
    return tr


def test_elementwise_chain():
    a = jnp.arange(16, dtype=jnp.float32)
    b = jnp.ones(16, jnp.float32) * 2
    tr = _check_outputs(lambda a, b: jnp.sum((a + b) * a - b), a, b)
    assert tr.n_instructions > 0


def test_matmul_reduction_argmax():
    A = jnp.asarray(np.random.default_rng(1).normal(size=(4, 5)), jnp.float32)
    B = jnp.asarray(np.random.default_rng(2).normal(size=(5, 3)), jnp.float32)

    def f(A, B):
        C = A @ B
        return jnp.max(C), jnp.argmax(C, axis=1), jnp.sum(C, axis=0)
    _check_outputs(f, A, B)


def test_control_flow_scan_while_cond():
    def f(x):
        def body(c, t):
            c = jax.lax.cond(t % 2 == 0, lambda c: c + x[t], lambda c: c * 0.5, c)
            return c, c
        c, ys = jax.lax.scan(body, 0.0, jnp.arange(6))

        def wcond(s):
            return s[0] < 3
        def wbody(s):
            return (s[0] + 1, s[1] + c)
        _, acc = jax.lax.while_loop(wcond, wbody, (jnp.int32(0), 0.0))
        return acc, ys
    x = jnp.arange(6, dtype=jnp.float32)
    _check_outputs(f, x)


def test_gather_scatter_dynamic():
    def f(x, idx, v, s):
        y = x[idx]                              # gather
        z = x.at[idx].add(v)                    # scatter-add
        w = jax.lax.dynamic_slice(z, (s,), (4,))
        return jnp.sum(y) + jnp.sum(w)
    x = jnp.arange(12, dtype=jnp.float32)
    idx = jnp.asarray([1, 5, 7], jnp.int32)
    v = jnp.ones(3, jnp.float32)
    _check_outputs(f, x, idx, v, jnp.int32(2))


def test_concat_pad_sort_select():
    def f(a, b):
        c = jnp.concatenate([a, b * 2])
        d = jnp.pad(c, (1, 1), constant_values=-1.0)
        e = jnp.sort(d)
        return jnp.where(e > 0, e, -e)
    a = jnp.asarray([3.0, -1.0, 2.0])
    b = jnp.asarray([0.5, -4.0])
    _check_outputs(f, a, b)


# ---------------------------------------------------------------- I-state
def test_pattern_variants_present():
    """The Fig. 4 variants must all arise: (a) load-load-op, (b) imm
    operand, (c) register-forwarded operand."""
    a = jnp.arange(32, dtype=jnp.int32)
    b = jnp.arange(32, dtype=jnp.int32)
    tr = trace_program(lambda a, b: jnp.sum((a + b) ^ 3), a, b)
    kinds = set()
    for inst in tr.trace:
        if inst.op in ("add", "xor"):
            tags = tuple(t for t, _ in inst.srcs)
            if tags == (SRC_REG, SRC_REG):
                kinds.add("reg_reg")
            if SRC_IMM in tags:
                kinds.add("imm")
    assert "reg_reg" in kinds and "imm" in kinds


def test_rut_iht_consistency():
    a = jnp.arange(8, dtype=jnp.float32)
    tr = trace_program(lambda a: jnp.sum(a * 2.0), a)
    for seq, entries in tr.iht.items():
        inst = tr.trace[seq]
        regs = [v for t, v in inst.srcs if t == SRC_REG]
        assert len(entries) == len(regs)
        for (r, pos), r2 in zip(entries, regs):
            assert r == r2
            # the recorded position must point at a write no later than seq
            writes = tr.rut[r]
            if 0 <= pos < len(writes):
                assert writes[pos] < seq or tr.trace[writes[pos]].dst == inst.dst
    # every dst register is within the file (+1 induction register)
    n_regs = max(tr.rut) + 1
    for inst in tr.trace:
        if inst.dst is not None:
            assert 0 <= inst.dst < n_regs


def test_loop_buffer_reuse_bounds_footprint():
    """Scan temporaries must recycle addresses (compiled-loop realism)."""
    def f(x):
        def body(c, t):
            y = x * t + c
            return jnp.sum(y) * 1e-3, jnp.max(y)
        return jax.lax.scan(body, 0.0, jnp.arange(64, dtype=jnp.float32))
    x = jnp.arange(64, dtype=jnp.float32)
    tr = trace_program(f, x)
    addrs = {i.addr for i in tr.trace if i.is_mem}
    # footprint far below one-buffer-per-iteration (64 iters x 64 floats)
    assert len(addrs) < 64 * 64


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.sampled_from(["add", "mul", "max"]))
def test_property_elementwise_matches_numpy(n, opname):
    r = np.random.default_rng(n)
    a = jnp.asarray(r.normal(size=(n,)), jnp.float32)
    b = jnp.asarray(r.normal(size=(n,)), jnp.float32)
    op = {"add": jnp.add, "mul": jnp.multiply, "max": jnp.maximum}[opname]
    tr = trace_program(lambda a, b: op(a, b), a, b)
    np.testing.assert_allclose(tr.outputs[0], np.asarray(op(a, b)), rtol=1e-6)
    # one store per output element
    assert sum(1 for i in tr.trace if i.is_store) == n
