"""All 17 paper workloads: VM output == jax.jit output (numerical ground
truth), plus a full profile producing finite, in-range metrics."""
import jax
import numpy as np
import pytest

from repro.core import OffloadConfig, profile_system, trace_program
from repro.workloads import WORKLOADS, build

FAST = sorted(WORKLOADS)


@pytest.mark.parametrize("name", FAST)
def test_workload_vm_matches_xla(name):
    fn, args = build(name)
    tr = trace_program(fn, *args)
    expected = jax.tree_util.tree_leaves(jax.jit(fn)(*args))
    assert len(tr.outputs) == len(expected)
    for got, exp in zip(tr.outputs, expected):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["LCS", "SSSP", "DT", "mcf"])
def test_workload_profile_in_range(name):
    fn, args = build(name)
    tr = trace_program(fn, *args)
    rep = profile_system(tr)
    assert 0.0 < rep.macr <= 1.0
    assert 0.5 < rep.energy_improvement < 10.0
    assert 0.5 < rep.speedup < 3.0
    assert np.isfinite(rep.base.total) and np.isfinite(rep.cim.total)


def test_lcs_is_cim_favorable():
    """§VI-A validation workload: LCS must clear the MACR ≥ 0.5 bar."""
    fn, args = build("LCS")
    tr = trace_program(fn, *args)
    rep = profile_system(tr)
    assert rep.cim_favorable
