#!/usr/bin/env python
"""Docs snippet-runner: execute every fenced ``python`` code block.

Usage::

    python tools/check_docs.py README.md docs/architecture.md
    python tools/check_docs.py --list README.md

Each ```python block is run in its own subprocess from the repo root with
``src/`` on PYTHONPATH, so documentation examples are tested exactly as a
reader would run them.  Blocks in other languages (```bash, ```text, ...)
are ignored — use those fences for anything not meant to execute.  A block
failure reports the file and the line the fence opened on, and the runner
exits non-zero if any block fails.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys
from typing import List, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def extract_python_blocks(path: pathlib.Path) -> List[Tuple[int, str]]:
    """(line-of-opening-fence, source) for every ```python block."""
    blocks: List[Tuple[int, str]] = []
    fence_line = 0
    lang = None
    buf: List[str] = []
    in_block = False
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        stripped = line.strip()
        if stripped.startswith("```"):
            if not in_block:
                in_block = True
                lang = stripped[3:].strip().lower()
                fence_line = lineno
                buf = []
            else:
                in_block = False
                if lang == "python":
                    blocks.append((fence_line, "\n".join(buf)))
        elif in_block:
            buf.append(line)
    return blocks


def run_block(source: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    src = str(REPO_ROOT / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    return subprocess.run([sys.executable, "-c", source], cwd=REPO_ROOT,
                          env=env, capture_output=True, text=True,
                          timeout=600)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="markdown files to check")
    ap.add_argument("--list", action="store_true",
                    help="enumerate blocks without running them")
    args = ap.parse_args(argv)

    failures = 0
    total = 0
    for name in args.files:
        path = pathlib.Path(name)
        if not path.is_absolute():
            path = REPO_ROOT / path
        blocks = extract_python_blocks(path)
        if not blocks:
            print(f"{name}: no python blocks")
            continue
        for fence_line, source in blocks:
            total += 1
            if args.list:
                head = source.strip().splitlines()[0] if source.strip() else ""
                print(f"{name}:{fence_line}: {head}")
                continue
            proc = run_block(source)
            status = "ok" if proc.returncode == 0 else "FAIL"
            print(f"{name}:{fence_line}: {status}")
            if proc.returncode != 0:
                failures += 1
                n_lines = len(source.splitlines())
                # the block body spans the lines between the fences
                print(f"[check_docs] failing block: {name} lines "
                      f"{fence_line + 1}-{fence_line + n_lines} "
                      f"(fence opened at line {fence_line})")
                for off, src_line in enumerate(source.splitlines(), 1):
                    print(f"  {fence_line + off:>5} | {src_line}")
                sys.stdout.write(proc.stdout)
                sys.stderr.write(proc.stderr)
    if not args.list:
        print(f"[check_docs] {total - failures}/{total} blocks passed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
